//! Bulk quantization: f32 slices → integer codes / fake-quant, fused with
//! the QEM statistics pass (single traversal — the L3 hot-path version of
//! `kernels/stats.py`). Serial backend of the engine's sliced-parallel
//! `codes_*` / `fake_quant_stats` dispatch (DESIGN.md §Kernel-Engine).

use super::format::{Format, FormatFamily, MinifloatKind};
use super::scheme::Scheme;

/// QEM statistics of one tensor under one scheme (mirrors kernels/stats.py).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantStats {
    /// Σ|x| before quantization.
    pub sum_abs: f64,
    /// max|x| before quantization.
    pub max_abs: f32,
    /// Σ|x̂| after quantization under the applied scheme.
    pub sum_abs_q: f64,
}

impl QuantStats {
    /// Paper Eq. 2: `Diff = log2(|Σ|x| − Σ|x̂|| / Σ|x| + 1)`.
    pub fn diff(&self) -> f64 {
        if self.sum_abs <= 0.0 {
            return 0.0;
        }
        ((self.sum_abs - self.sum_abs_q).abs() / self.sum_abs + 1.0).log2()
    }

    /// Relative mean error (the pre-log ratio; the paper's "3%" threshold).
    pub fn ratio(&self) -> f64 {
        if self.sum_abs <= 0.0 {
            return 0.0;
        }
        (self.sum_abs - self.sum_abs_q).abs() / self.sum_abs
    }
}

/// Fake-quantize `xs` in place and return the fused QEM statistics.
///
/// One traversal computes Σ|x|, max|x| and Σ|x̂| while writing x̂ — this is
/// the hot path of the pure-Rust training substrate, kept allocation-free.
pub fn fake_quant_stats_inplace(xs: &mut [f32], sch: Scheme) -> QuantStats {
    let r = sch.resolution();
    let inv_r = 1.0 / r;
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    let mut sum_abs = 0.0f64;
    let mut sum_abs_q = 0.0f64;
    let mut max_abs = 0.0f32;
    for x in xs.iter_mut() {
        let v = *x;
        let a = v.abs();
        sum_abs += a as f64;
        if a > max_abs {
            max_abs = a;
        }
        let q = (v * inv_r).round_ties_even().clamp(lo, hi) * r;
        sum_abs_q += q.abs() as f64;
        *x = q;
    }
    QuantStats { sum_abs, max_abs, sum_abs_q }
}

/// Fake-quantize out of place (`out` must match `xs` length).
pub fn fake_quant_into(xs: &[f32], out: &mut [f32], sch: Scheme) -> QuantStats {
    assert_eq!(xs.len(), out.len());
    out.copy_from_slice(xs);
    fake_quant_stats_inplace(out, sch)
}

/// Statistics only (no mutation) — used by QEM probes at update iterations.
pub fn stats_only(xs: &[f32], sch: Scheme) -> QuantStats {
    let r = sch.resolution();
    let inv_r = 1.0 / r;
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    let mut sum_abs = 0.0f64;
    let mut sum_abs_q = 0.0f64;
    let mut max_abs = 0.0f32;
    for &v in xs {
        let a = v.abs();
        sum_abs += a as f64;
        if a > max_abs {
            max_abs = a;
        }
        let q = (v * inv_r).round_ties_even().clamp(lo, hi) * r;
        sum_abs_q += q.abs() as f64;
    }
    QuantStats { sum_abs, max_abs, sum_abs_q }
}

/// Max |x| of a slice (the paper's `Z` / `Range` probe).
pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
}

/// Format-generic [`fake_quant_stats_inplace`]: fixed-point and int4 route
/// to the pinned scheme kernel (bit-identical to before the format axis
/// existed); minifloat runs the scaled fp8 codec elementwise.
pub fn fake_quant_stats_inplace_fmt(xs: &mut [f32], fmt: Format) -> QuantStats {
    match fmt {
        Format::FixedPoint(sch) => fake_quant_stats_inplace(xs, sch),
        Format::Int4 { s } => fake_quant_stats_inplace(xs, Scheme { bits: 4, s }),
        Format::Minifloat { kind, s } => {
            let r = (s as f32).exp2();
            let inv_r = 1.0 / r;
            let mut sum_abs = 0.0f64;
            let mut sum_abs_q = 0.0f64;
            let mut max = 0.0f32;
            for x in xs.iter_mut() {
                let v = *x;
                let a = v.abs();
                sum_abs += a as f64;
                if a > max {
                    max = a;
                }
                let q = kind.decode(kind.encode(v * inv_r)) * r;
                sum_abs_q += q.abs() as f64;
                *x = q;
            }
            QuantStats { sum_abs, max_abs: max, sum_abs_q }
        }
    }
}

/// Format-generic [`stats_only`] (no mutation) — the QEM probe for
/// non-fixed-point families.
pub fn stats_only_fmt(xs: &[f32], fmt: Format) -> QuantStats {
    match fmt {
        Format::FixedPoint(sch) => stats_only(xs, sch),
        Format::Int4 { s } => stats_only(xs, Scheme { bits: 4, s }),
        Format::Minifloat { kind, s } => {
            let r = (s as f32).exp2();
            let inv_r = 1.0 / r;
            let mut sum_abs = 0.0f64;
            let mut sum_abs_q = 0.0f64;
            let mut max = 0.0f32;
            for &v in xs {
                let a = v.abs();
                sum_abs += a as f64;
                if a > max {
                    max = a;
                }
                let q = kind.decode(kind.encode(v * inv_r)) * r;
                sum_abs_q += q.abs() as f64;
            }
            QuantStats { sum_abs, max_abs: max, sum_abs_q }
        }
    }
}

/// Quantize to fp8 byte codes under a scaled minifloat format.
pub fn codes_f8(xs: &[f32], out: &mut [u8], kind: MinifloatKind, s: i32) {
    debug_assert_eq!(xs.len(), out.len());
    let inv_r = 1.0 / (s as f32).exp2();
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = kind.encode(x * inv_r);
    }
}

/// Decode fp8 byte codes back to f32 under a scaled minifloat format.
pub fn decode_f8(codes: &[u8], out: &mut [f32], kind: MinifloatKind, s: i32) {
    debug_assert_eq!(codes.len(), out.len());
    let r = (s as f32).exp2();
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = kind.decode(c) * r;
    }
}

/// Per-channel scale exponents for a row-major `rows × cols` weight matrix
/// with one channel per **row** (conv layout: `[out_c, fan_in]`): each
/// channel gets the family's scale rule on its own max-abs, at the
/// per-tensor decided `bits`.
pub fn channel_scales_rows(
    w: &[f32],
    rows: usize,
    cols: usize,
    family: FormatFamily,
    bits: u8,
) -> Vec<i32> {
    assert_eq!(w.len(), rows * cols);
    (0..rows)
        .map(|r| {
            let z = max_abs(&w[r * cols..(r + 1) * cols]);
            Format::for_range(family, z, bits).scale_exp()
        })
        .collect()
}

/// [`channel_scales_rows`] with one channel per **column** (fc layout:
/// `[d_in, d_out]`, output features along columns).
pub fn channel_scales_cols(
    w: &[f32],
    rows: usize,
    cols: usize,
    family: FormatFamily,
    bits: u8,
) -> Vec<i32> {
    assert_eq!(w.len(), rows * cols);
    let mut z = vec![0.0f32; cols];
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        for (zc, &v) in z.iter_mut().zip(row) {
            *zc = zc.max(v.abs());
        }
    }
    z.iter().map(|&zc| Format::for_range(family, zc, bits).scale_exp()).collect()
}

/// Fake-quantize a row-major `rows × cols` matrix with one scale per row
/// (conv weights). `scales[r]` carries the per-channel exponent; family and
/// `bits` are the tensor-wide decision. Returns fused QEM stats.
pub fn fake_quant_per_channel_rows(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    family: FormatFamily,
    bits: u8,
    scales: &[i32],
) -> QuantStats {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(scales.len(), rows);
    let mut st = QuantStats::default();
    for (r, &s) in scales.iter().enumerate() {
        let fmt = Format::from_scheme(family, Scheme { bits, s });
        let row = fake_quant_stats_inplace_fmt(&mut w[r * cols..(r + 1) * cols], fmt);
        st.sum_abs += row.sum_abs;
        st.sum_abs_q += row.sum_abs_q;
        st.max_abs = st.max_abs.max(row.max_abs);
    }
    st
}

/// [`fake_quant_per_channel_rows`] with one scale per column (fc weights).
pub fn fake_quant_per_channel_cols(
    w: &mut [f32],
    rows: usize,
    cols: usize,
    family: FormatFamily,
    bits: u8,
    scales: &[i32],
) -> QuantStats {
    assert_eq!(w.len(), rows * cols);
    assert_eq!(scales.len(), cols);
    let mut st = QuantStats::default();
    let fmts: Vec<Format> =
        scales.iter().map(|&s| Format::from_scheme(family, Scheme { bits, s })).collect();
    for r in 0..rows {
        for (c, fmt) in fmts.iter().enumerate() {
            let i = r * cols + c;
            let v = w[i];
            let a = v.abs();
            st.sum_abs += a as f64;
            if a > st.max_abs {
                st.max_abs = a;
            }
            let q = fmt.fake_quant(v);
            st.sum_abs_q += q.abs() as f64;
            w[i] = q;
        }
    }
    st
}

/// Quantize to i8 codes (for the integer GEMM hot path). Panics in debug if
/// the scheme is wider than 8 bits.
pub fn codes_i8(xs: &[f32], out: &mut [i8], sch: Scheme) {
    debug_assert!(sch.bits <= 8);
    debug_assert_eq!(xs.len(), out.len());
    let inv_r = 1.0 / sch.resolution();
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = (x * inv_r).round_ties_even().clamp(lo, hi) as i8;
    }
}

/// Quantize to i16 codes.
pub fn codes_i16(xs: &[f32], out: &mut [i16], sch: Scheme) {
    debug_assert!(sch.bits <= 16);
    debug_assert_eq!(xs.len(), out.len());
    let inv_r = 1.0 / sch.resolution();
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = (x * inv_r).round_ties_even().clamp(lo, hi) as i16;
    }
}

/// Quantize to i32 codes (int24 schemes use i32 storage).
pub fn codes_i32(xs: &[f32], out: &mut [i32], sch: Scheme) {
    debug_assert_eq!(xs.len(), out.len());
    let inv_r = 1.0 / sch.resolution();
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = (x * inv_r).round_ties_even().clamp(lo, hi) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::Pcg32;

    fn randvec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal() * scale).collect()
    }

    #[test]
    fn stats_match_scalar_path() {
        let xs = randvec(1, 1000, 2.0);
        let sch = Scheme::for_range(max_abs(&xs), 8);
        let st = stats_only(&xs, sch);
        let mut ys = xs.clone();
        let st2 = fake_quant_stats_inplace(&mut ys, sch);
        assert_eq!(st, st2);
        // mutation matches per-element fake_quant
        for (&x, &y) in xs.iter().zip(&ys) {
            assert_eq!(y, sch.fake_quant(x));
        }
    }

    #[test]
    fn diff_formula() {
        let st = QuantStats { sum_abs: 100.0, sum_abs_q: 97.0, max_abs: 1.0 };
        assert!((st.diff() - (1.03f64).log2()).abs() < 1e-12);
        assert!((st.ratio() - 0.03).abs() < 1e-12);
    }

    #[test]
    fn diff_zero_cases() {
        assert_eq!(QuantStats::default().diff(), 0.0);
        let st = QuantStats { sum_abs: 5.0, sum_abs_q: 5.0, max_abs: 1.0 };
        assert_eq!(st.diff(), 0.0);
    }

    #[test]
    fn prop_diff_monotone_in_bits() {
        check("diff-monotone-bits", 40, |g| {
            let _sc = g.f32_log(1e-3, 1e3);
            let xs = g.normal_vec(512, _sc);
            let z = max_abs(&xs);
            let d8 = stats_only(&xs, Scheme::for_range(z, 8)).diff();
            let d16 = stats_only(&xs, Scheme::for_range(z, 16)).diff();
            let d24 = stats_only(&xs, Scheme::for_range(z, 24)).diff();
            assert!(d8 >= d16 - 1e-9 && d16 >= d24 - 1e-9, "{d8} {d16} {d24}");
            assert!(d24 < 1e-3);
        });
    }

    #[test]
    fn prop_codes_match_fake_quant() {
        check("codes-vs-fq", 30, |g| {
            let _sc = g.f32_log(1e-2, 1e2);
            let xs = g.normal_vec(128, _sc);
            let sch = Scheme::for_range(max_abs(&xs), 8);
            let mut c = vec![0i8; xs.len()];
            codes_i8(&xs, &mut c, sch);
            for (&x, &code) in xs.iter().zip(&c) {
                assert_eq!(code as f32 * sch.resolution(), sch.fake_quant(x));
            }
            let sch16 = Scheme::for_range(max_abs(&xs), 16);
            let mut c16 = vec![0i16; xs.len()];
            codes_i16(&xs, &mut c16, sch16);
            for (&x, &code) in xs.iter().zip(&c16) {
                assert_eq!(code as f32 * sch16.resolution(), sch16.fake_quant(x));
            }
        });
    }

    #[test]
    fn large_variance_has_larger_diff_than_uniformish() {
        // Observation 1/3 of the paper: centralized long-tail distributions
        // (large σ relative to resolution) suffer more at int8.
        let mut r = Pcg32::seeded(2);
        // long-tailed: mixture of small and huge values
        let long_tail: Vec<f32> = (0..4096)
            .map(|i| if i % 100 == 0 { r.normal() * 100.0 } else { r.normal() * 0.1 })
            .collect();
        let uniform: Vec<f32> = (0..4096).map(|_| r.range(-1.0, 1.0)).collect();
        let d_tail = stats_only(&long_tail, Scheme::for_range(max_abs(&long_tail), 8)).diff();
        let d_unif = stats_only(&uniform, Scheme::for_range(max_abs(&uniform), 8)).diff();
        assert!(d_tail > d_unif, "tail={d_tail} unif={d_unif}");
    }
}
