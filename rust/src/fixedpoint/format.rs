//! Format family: the generalization of [`Scheme`] from "how many
//! fixed-point bits" to "which number format" (DESIGN.md §Formats).
//!
//! The paper's controllers adapt a symmetric fixed-point width; AdaPT
//! (arXiv 2107.13490) and the OCP FP8 formats argue the range-vs-precision
//! tradeoff is really a choice of format *family*. This module adds:
//!
//! - [`MinifloatKind`]: the two OCP 8-bit minifloats (E4M3, E5M2) with a
//!   saturating, NaN/Inf-safe codec (reserved NaN/Inf patterns are never
//!   emitted; `encode(NaN) = 0`, out-of-range magnitudes clamp to the
//!   largest finite value).
//! - [`Format`]: fixed-point (the existing [`Scheme`]), scaled minifloat
//!   (`2^s · fp8`), and int4 (a 4-bit fixed-point scheme with nibble-packed
//!   storage, weight-only in serving).
//! - [`QuantAxis`]: per-tensor vs per-channel scale selection for conv/fc
//!   weights.
//! - [`pack_nibbles`]/[`unpack_nibbles`]: two int4 codes per byte for the
//!   weight-only GEMM hot path.
//!
//! Fixed-point stays the default family everywhere; a config that never
//! mentions a minifloat or int4 format takes exactly the code paths it took
//! before this module existed (bit-identity pinned by `test_formats.rs`).

use super::scheme::Scheme;

/// The two OCP 8-bit minifloat formats.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MinifloatKind {
    /// 1 sign + 4 exponent + 3 mantissa, bias 7, max finite 448.
    E4M3,
    /// 1 sign + 5 exponent + 2 mantissa, bias 15, max finite 57344.
    E5M2,
}

impl MinifloatKind {
    /// (exponent bits, mantissa bits, bias).
    #[inline]
    pub fn spec(&self) -> (u32, u32, i32) {
        match self {
            MinifloatKind::E4M3 => (4, 3, 7),
            MinifloatKind::E5M2 => (5, 2, 15),
        }
    }

    /// Largest finite representable magnitude (OCP: 448 / 57344).
    #[inline]
    pub fn max_normal(&self) -> f32 {
        match self {
            MinifloatKind::E4M3 => 448.0,
            MinifloatKind::E5M2 => 57344.0,
        }
    }

    /// Code of the largest finite magnitude (sign bit clear).
    #[inline]
    pub fn max_code(&self) -> u8 {
        match self {
            MinifloatKind::E4M3 => (15 << 3) | 6, // 2^8 · 1.75 = 448
            MinifloatKind::E5M2 => (30 << 2) | 3, // 2^15 · 1.75 = 57344
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            MinifloatKind::E4M3 => "e4m3",
            MinifloatKind::E5M2 => "e5m2",
        }
    }

    /// The format family this kind belongs to.
    pub fn family(&self) -> FormatFamily {
        match self {
            MinifloatKind::E4M3 => FormatFamily::E4M3,
            MinifloatKind::E5M2 => FormatFamily::E5M2,
        }
    }

    /// Encode a value to its 8-bit pattern: saturating (no Inf codes),
    /// NaN → +0, round-ties-even in the mantissa, subnormals supported.
    pub fn encode(&self, x: f32) -> u8 {
        if x.is_nan() {
            return 0;
        }
        let (ebits, mbits, bias) = self.spec();
        let sign = if x.is_sign_negative() { 1u8 << (ebits + mbits) } else { 0 };
        let a = x.abs();
        if !a.is_finite() {
            return sign | self.max_code();
        }
        if a == 0.0 {
            return 0;
        }
        let min_exp = 1 - bias; // exponent of the smallest normal
        // floor(log2(a)) from the f32 exponent field (f32 subnormals map
        // below min_exp and clamp, which is what the codec wants).
        let e_f32 = ((a.to_bits() >> 23) & 0xff) as i32 - 127;
        let mut e = e_f32.max(min_exp);
        let quantum = ((e - mbits as i32) as f32).exp2();
        let mut m = (a / quantum).round_ties_even() as u32;
        if m >= 1 << (mbits + 1) {
            // mantissa carry: 1.111..1 rounded up to 10.00..0
            e += 1;
            m = 1 << mbits;
        }
        // overflow past the largest finite value saturates
        let val = m as f32 * ((e - mbits as i32) as f32).exp2();
        if val > self.max_normal() {
            return sign | self.max_code();
        }
        if m == 0 {
            return 0; // rounded to zero: canonical +0
        }
        if m < 1 << mbits {
            sign | m as u8 // subnormal: biased exponent 0
        } else {
            let be = (e + bias) as u8;
            sign | (be << mbits) | (m - (1 << mbits)) as u8
        }
    }

    /// Decode an 8-bit pattern. Reserved NaN/Inf patterns are never emitted
    /// by [`encode`](Self::encode); if fed in anyway they decode through the
    /// same formula (finite, monotone), keeping the codec total.
    pub fn decode(&self, code: u8) -> f32 {
        let (ebits, mbits, bias) = self.spec();
        let mf = (code & ((1 << mbits) - 1)) as u32;
        let be = ((code >> mbits) & ((1 << ebits) - 1)) as i32;
        let sign = if code >> (ebits + mbits) != 0 { -1.0f32 } else { 1.0 };
        let mag = if be == 0 {
            mf as f32 * ((1 - bias - mbits as i32) as f32).exp2()
        } else {
            ((1u32 << mbits) + mf) as f32 * ((be - bias - mbits as i32) as f32).exp2()
        };
        sign * mag
    }

    /// Fake-quantize one value through the codec (no external scale).
    #[inline]
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.decode(self.encode(x))
    }
}

/// Which format family a controller adapts within. `FixedPoint` is the
/// paper's original axis (QPA grows the bit-width); the other families have
/// a fixed storage width, so QPA only tracks the scale exponent.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FormatFamily {
    /// Symmetric fixed-point, 2..=32 bits (the default — today's behavior).
    FixedPoint,
    /// OCP E4M3 minifloat with a power-of-two tensor scale.
    E4M3,
    /// OCP E5M2 minifloat with a power-of-two tensor scale.
    E5M2,
    /// 4-bit symmetric fixed-point, nibble-packed storage (weight-only in
    /// serving).
    Int4,
}

impl Default for FormatFamily {
    /// Fixed-point is the paper's axis and the default everywhere.
    fn default() -> Self {
        FormatFamily::FixedPoint
    }
}

impl FormatFamily {
    /// Storage bits per element.
    #[inline]
    pub fn storage_bits(&self) -> u8 {
        match self {
            FormatFamily::FixedPoint => 0, // variable; see `Scheme::bits`
            FormatFamily::E4M3 | FormatFamily::E5M2 => 8,
            FormatFamily::Int4 => 4,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            FormatFamily::FixedPoint => "fixed",
            FormatFamily::E4M3 => "e4m3",
            FormatFamily::E5M2 => "e5m2",
            FormatFamily::Int4 => "int4",
        }
    }

    /// Parse a family label (`fixed`, `e4m3`, `e5m2`, `int4`).
    pub fn parse(s: &str) -> Option<FormatFamily> {
        match s {
            "fixed" | "fixedpoint" => Some(FormatFamily::FixedPoint),
            "e4m3" => Some(FormatFamily::E4M3),
            "e5m2" => Some(FormatFamily::E5M2),
            "int4" => Some(FormatFamily::Int4),
            _ => None,
        }
    }

    /// Checkpoint tag (v4 controller records).
    pub fn tag(&self) -> &'static str {
        self.label()
    }
}

/// A concrete quantization format: family + the parameters the controller
/// adapts. This is the generalization of [`Scheme`] that the stash, wire,
/// and compiler layers dispatch on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Format {
    /// The paper's symmetric fixed-point scheme.
    FixedPoint(Scheme),
    /// `x ≈ 2^s · fp8(x / 2^s)` — minifloat with a power-of-two scale.
    Minifloat { kind: MinifloatKind, s: i32 },
    /// 4-bit symmetric fixed-point (`Scheme { bits: 4, s }` semantics,
    /// nibble-packed storage).
    Int4 { s: i32 },
}

impl Format {
    /// Build the format a controller applies: its family plus the scheme
    /// slot it adapts (`bits` for fixed-point, `s` reused as the scale
    /// exponent for the fixed-width families).
    pub fn from_scheme(family: FormatFamily, sch: Scheme) -> Format {
        match family {
            FormatFamily::FixedPoint => Format::FixedPoint(sch),
            FormatFamily::E4M3 => Format::Minifloat { kind: MinifloatKind::E4M3, s: sch.s },
            FormatFamily::E5M2 => Format::Minifloat { kind: MinifloatKind::E5M2, s: sch.s },
            FormatFamily::Int4 => Format::Int4 { s: sch.s },
        }
    }

    /// Scale rule per family for max-abs `Z` (the generalization of
    /// [`Scheme::for_range`]): fixed-point covers `Z` with `2^s·qmax`,
    /// minifloat picks `s = ceil(log2(Z / max_normal))` so `Z/2^s` fits the
    /// finite range. Zero/NaN/Inf `Z` falls back like `Scheme::for_range`.
    pub fn for_range(family: FormatFamily, max_abs: f32, bits: u8) -> Format {
        match family {
            FormatFamily::FixedPoint => Format::FixedPoint(Scheme::for_range(max_abs, bits)),
            FormatFamily::Int4 => Format::Int4 { s: Scheme::for_range(max_abs, 4).s },
            FormatFamily::E4M3 | FormatFamily::E5M2 => {
                let kind = if family == FormatFamily::E4M3 {
                    MinifloatKind::E4M3
                } else {
                    MinifloatKind::E5M2
                };
                let s = if max_abs > 0.0 && max_abs.is_finite() {
                    ((max_abs / kind.max_normal()).log2().ceil() as i32).clamp(-126, 127)
                } else {
                    0
                };
                Format::Minifloat { kind, s }
            }
        }
    }

    #[inline]
    pub fn family(&self) -> FormatFamily {
        match self {
            Format::FixedPoint(_) => FormatFamily::FixedPoint,
            Format::Minifloat { kind: MinifloatKind::E4M3, .. } => FormatFamily::E4M3,
            Format::Minifloat { kind: MinifloatKind::E5M2, .. } => FormatFamily::E5M2,
            Format::Int4 { .. } => FormatFamily::Int4,
        }
    }

    /// Storage bits per element.
    #[inline]
    pub fn storage_bits(&self) -> u8 {
        match self {
            Format::FixedPoint(sch) => sch.bits,
            Format::Minifloat { .. } => 8,
            Format::Int4 { .. } => 4,
        }
    }

    /// Scale exponent (the `s` slot the controller adapts).
    #[inline]
    pub fn scale_exp(&self) -> i32 {
        match self {
            Format::FixedPoint(sch) => sch.s,
            Format::Minifloat { s, .. } | Format::Int4 { s } => *s,
        }
    }

    /// The fixed-point view of this format, if it has one (int4 is a 4-bit
    /// scheme; minifloat has none).
    #[inline]
    pub fn as_scheme(&self) -> Option<Scheme> {
        match self {
            Format::FixedPoint(sch) => Some(*sch),
            Format::Int4 { s } => Some(Scheme { bits: 4, s: *s }),
            Format::Minifloat { .. } => None,
        }
    }

    /// Finest representable step near zero (fixed-point: `2^s`; minifloat:
    /// the scaled subnormal quantum). Generalizes [`Scheme::resolution`].
    pub fn resolution(&self) -> f32 {
        match self {
            Format::FixedPoint(sch) => sch.resolution(),
            Format::Int4 { s } => (*s as f32).exp2(),
            Format::Minifloat { kind, s } => {
                let (_, mbits, bias) = kind.spec();
                ((s + 1 - bias - mbits as i32) as f32).exp2()
            }
        }
    }

    /// Largest representable magnitude (generalizes `r·qmax`).
    pub fn range_top(&self) -> f32 {
        match self {
            Format::FixedPoint(sch) => sch.range_top(),
            Format::Int4 { s } => (Scheme { bits: 4, s: *s }).range_top(),
            Format::Minifloat { kind, s } => (*s as f32).exp2() * kind.max_normal(),
        }
    }

    /// Fake-quantize one value (saturating, NaN-safe: NaN → 0).
    pub fn fake_quant(&self, x: f32) -> f32 {
        match self {
            Format::FixedPoint(sch) => sch.fake_quant(x),
            Format::Int4 { s } => (Scheme { bits: 4, s: *s }).fake_quant(x),
            Format::Minifloat { kind, s } => {
                let r = (*s as f32).exp2();
                kind.decode(kind.encode(x / r)) * r
            }
        }
    }

    /// Reporting label (`int8`/`int16`/… for fixed-point widths, the family
    /// label otherwise) — what the format-aware ledger mix strings print.
    pub fn label(&self) -> String {
        match self {
            Format::FixedPoint(sch) => format!("int{}", sch.bits),
            Format::Minifloat { kind, .. } => kind.label().to_string(),
            Format::Int4 { .. } => "int4".to_string(),
        }
    }
}

/// Scale granularity for weight quantization (Sakr & Shanbhag, arXiv
/// 1812.11732: per-tensor precision criteria map naturally onto per-channel
/// scales). Bit-width / family decisions stay per-tensor; only the scale
/// exponent varies per channel.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantAxis {
    /// One scale for the whole tensor (the default — today's behavior).
    PerTensor,
    /// One scale per channel along the given dimension (conv: output
    /// channel; fc: output feature).
    PerChannel(usize),
}

/// Pack int4 codes two per byte: element `2i` in the low nibble, `2i+1` in
/// the high nibble; odd lengths pad the final high nibble with 0. Codes must
/// already be in the int4 range `[-8, 7]`.
pub fn pack_nibbles(codes: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = (pair[0] as u8) & 0x0f;
        let hi = if pair.len() == 2 { (pair[1] as u8) & 0x0f } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack [`pack_nibbles`] output into sign-extended i8 codes. `out.len()`
/// selects how many elements to recover (the packed slice must hold them).
pub fn unpack_nibbles(packed: &[u8], out: &mut [i8]) {
    assert!(
        packed.len() >= out.len().div_ceil(2),
        "packed int4 buffer too short: {} bytes for {} codes",
        packed.len(),
        out.len()
    );
    for (i, o) in out.iter_mut().enumerate() {
        let byte = packed[i / 2];
        let nib = if i % 2 == 0 { byte & 0x0f } else { byte >> 4 };
        // sign-extend the 4-bit two's-complement nibble
        *o = ((nib << 4) as i8) >> 4;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minifloat_decode_known_values() {
        let k = MinifloatKind::E4M3;
        assert_eq!(k.decode(0), 0.0);
        assert_eq!(k.decode(k.max_code()), 448.0);
        assert_eq!(k.decode(0x80 | k.max_code()), -448.0);
        // smallest subnormal: 2^-6 / 8 = 2^-9
        assert_eq!(k.decode(1), (-9f32).exp2());
        let k = MinifloatKind::E5M2;
        assert_eq!(k.decode(k.max_code()), 57344.0);
        assert_eq!(k.decode(1), (-16f32).exp2()); // 2^-14 / 4
    }

    #[test]
    fn minifloat_encode_exact_on_representables() {
        // every decodable finite magnitude round-trips exactly
        for kind in [MinifloatKind::E4M3, MinifloatKind::E5M2] {
            for code in 0..=kind.max_code() {
                let v = kind.decode(code);
                assert_eq!(kind.encode(v), code, "{} code {code} v {v}", kind.label());
                let neg = kind.decode(0x80 | code);
                if code != 0 {
                    assert_eq!(kind.encode(neg), 0x80 | code);
                }
            }
        }
    }

    #[test]
    fn minifloat_nan_inf_safe() {
        for kind in [MinifloatKind::E4M3, MinifloatKind::E5M2] {
            assert_eq!(kind.encode(f32::NAN), 0);
            assert_eq!(kind.decode(kind.encode(f32::INFINITY)), kind.max_normal());
            assert_eq!(kind.decode(kind.encode(f32::NEG_INFINITY)), -kind.max_normal());
            assert_eq!(kind.encode(1e30), kind.max_code());
        }
    }

    #[test]
    fn minifloat_round_ties_even() {
        let k = MinifloatKind::E4M3;
        // between 1.0 (code m=8) and 1.125 (m=9): midpoint 1.0625 → even m=8
        assert_eq!(k.fake_quant(1.0625), 1.0);
        // between 1.125 and 1.25: midpoint 1.1875 → even m=10 → 1.25
        assert_eq!(k.fake_quant(1.1875), 1.25);
    }

    #[test]
    fn minifloat_mantissa_carry() {
        let k = MinifloatKind::E4M3;
        // 1.96875 = 1.1111(1) just below 2.0: rounds up across the binade
        assert_eq!(k.fake_quant(1.97), 2.0);
        // carry at the top of the range saturates instead of overflowing
        assert_eq!(k.fake_quant(447.9), 448.0);
        assert_eq!(k.fake_quant(460.0), 448.0);
        assert_eq!(k.fake_quant(465.0), 448.0);
    }

    #[test]
    fn minifloat_fake_quant_monotone() {
        for kind in [MinifloatKind::E4M3, MinifloatKind::E5M2] {
            let mut prev = f32::NEG_INFINITY;
            let mut x = -500.0f32;
            while x <= 500.0 {
                let q = kind.fake_quant(x);
                assert!(q >= prev, "{} non-monotone at {x}: {q} < {prev}", kind.label());
                prev = q;
                x += 0.37;
            }
        }
    }

    #[test]
    fn format_scale_rule_covers_range() {
        for family in [FormatFamily::E4M3, FormatFamily::E5M2, FormatFamily::Int4] {
            for &z in &[1e-5f32, 0.3, 1.0, 77.0, 1e6] {
                let f = Format::for_range(family, z, 8);
                assert!(
                    f.range_top() >= z * (1.0 - 1e-6),
                    "{:?} z={z} top={}",
                    family,
                    f.range_top()
                );
            }
        }
    }

    #[test]
    fn format_fixedpoint_matches_scheme_exactly() {
        let sch = Scheme::for_range(3.7, 8);
        let f = Format::FixedPoint(sch);
        for &x in &[0.0f32, 0.1, -2.5, 3.69, 100.0, -100.0] {
            assert_eq!(f.fake_quant(x), sch.fake_quant(x));
        }
        assert_eq!(f.resolution(), sch.resolution());
        assert_eq!(f.range_top(), sch.range_top());
    }

    #[test]
    fn format_int4_is_four_bit_scheme() {
        let f = Format::for_range(FormatFamily::Int4, 7.0, 8);
        let sch = Scheme::for_range(7.0, 4);
        assert_eq!(f.as_scheme(), Some(sch));
        for &x in &[0.0f32, 1.0, -6.9, 7.0, 50.0] {
            assert_eq!(f.fake_quant(x), sch.fake_quant(x));
        }
    }

    #[test]
    fn format_zero_range_fallback() {
        for family in [FormatFamily::E4M3, FormatFamily::E5M2] {
            for z in [0.0f32, f32::NAN, f32::INFINITY] {
                let f = Format::for_range(family, z, 8);
                assert_eq!(f.scale_exp(), 0, "{:?} z={z}", family);
                assert_eq!(f.fake_quant(0.0), 0.0);
            }
        }
    }

    #[test]
    fn format_labels() {
        assert_eq!(Format::FixedPoint(Scheme { bits: 8, s: 0 }).label(), "int8");
        assert_eq!(Format::FixedPoint(Scheme { bits: 16, s: 0 }).label(), "int16");
        assert_eq!(Format::Minifloat { kind: MinifloatKind::E4M3, s: 0 }.label(), "e4m3");
        assert_eq!(Format::Int4 { s: 0 }.label(), "int4");
        assert_eq!(FormatFamily::parse("e5m2"), Some(FormatFamily::E5M2));
        assert_eq!(FormatFamily::parse("nope"), None);
    }

    #[test]
    fn nibble_pack_round_trip() {
        let codes: Vec<i8> = (-8..=7).collect();
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), 8);
        let mut back = vec![0i8; codes.len()];
        unpack_nibbles(&packed, &mut back);
        assert_eq!(back, codes);
        // odd length pads
        let odd = [3i8, -8, 7];
        let p = pack_nibbles(&odd);
        assert_eq!(p.len(), 2);
        let mut b = vec![0i8; 3];
        unpack_nibbles(&p, &mut b);
        assert_eq!(b, odd);
    }

    #[test]
    fn minifloat_scaled_fake_quant() {
        // values far outside the bare fp8 range quantize fine under a scale
        let f = Format::for_range(FormatFamily::E4M3, 1.0e6, 8);
        let q = f.fake_quant(9.0e5);
        assert!((q - 9.0e5).abs() / 9.0e5 < 0.05, "q={q}");
        assert_eq!(f.fake_quant(f32::NAN), 0.0);
    }
}
