//! Fixed-point quantization scheme (paper Appendix B, "scheme 1").
//!
//! A scheme is a bit-width `n` and a power-of-two resolution `r = 2^s`.
//! Codes are `I = clamp(round(F / r), -2^(n-1), 2^(n-1)-1)` and the
//! dequantized value is `F̂ = r·I`, so the representable range is
//! `[r·qmin, r·qmax]` (Table 4). This file is the single source of truth for
//! scheme math on the Rust side and is pinned against `kernels/ref.py` via
//! the shared test vectors in `rust/tests/test_cross_oracle.rs`.

/// Bit-widths the paper's QPA steps through (n' = 8 growth).
pub const BIT_STEPS: [u8; 4] = [8, 16, 24, 32];

/// A fixed-point quantization scheme: bit-width + resolution exponent.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Scheme {
    /// Total bit-width n (sign + (n-1)-bit magnitude), 2..=32.
    pub bits: u8,
    /// Resolution exponent s with r = 2^s.
    pub s: i32,
}

impl Scheme {
    /// Largest representable code (2^(n-1) − 1).
    #[inline]
    pub fn qmax(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Smallest representable code (−2^(n-1)).
    #[inline]
    pub fn qmin(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    /// Resolution r = 2^s.
    #[inline]
    pub fn resolution(&self) -> f32 {
        (self.s as f32).exp2()
    }

    /// Representable range top, r·qmax (≈ the paper's `Range`).
    #[inline]
    pub fn range_top(&self) -> f32 {
        self.resolution() * self.qmax() as f32
    }

    /// The paper's scale rule: `s = ceil(log2(Z / (2^(n-1) − 1)))` for
    /// max-abs `Z`. Zero/non-finite Z falls back to s = −(n−1) (range ~[−1,1]).
    pub fn for_range(max_abs: f32, bits: u8) -> Scheme {
        assert!((2..=32).contains(&bits), "bits out of range: {bits}");
        let q_top = ((1i64 << (bits - 1)) - 1) as f32;
        let s = if max_abs > 0.0 && max_abs.is_finite() {
            (max_abs / q_top).log2().ceil() as i32
        } else {
            -(bits as i32 - 1)
        };
        Scheme { bits, s }
    }

    /// Quantize one value to its integer code.
    #[inline]
    pub fn code(&self, x: f32) -> i32 {
        let r = self.resolution();
        let q = (x / r).round_ties_even_away(); // see helper below
        q.clamp(self.qmin() as f32, self.qmax() as f32) as i32
    }

    /// Dequantize a code.
    #[inline]
    pub fn decode(&self, code: i32) -> f32 {
        code as f32 * self.resolution()
    }

    /// Fake-quantize one value (quantize + dequantize).
    #[inline]
    pub fn fake_quant(&self, x: f32) -> f32 {
        self.decode(self.code(x))
    }
}

/// Rounding helper matching `jnp.round` / `np.round` (banker's rounding,
/// round-half-to-even) so the Rust substrate is bit-identical to the oracle.
pub trait RoundTiesEven {
    fn round_ties_even_away(self) -> f32;
}

impl RoundTiesEven for f32 {
    #[inline]
    fn round_ties_even_away(self) -> f32 {
        // f32::round_ties_even is stable since 1.77.
        self.round_ties_even()
    }
}

/// The three tensor roles Algorithm 1 quantizes per layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TensorKind {
    /// Weights W_l (pinned int8 in the paper's experiments).
    Weight,
    /// Activations X_l (pinned int8).
    Activation,
    /// Activation gradients ΔX_{l+1} (adaptive int8/16/24).
    Gradient,
}

impl TensorKind {
    pub const ALL: [TensorKind; 3] = [TensorKind::Weight, TensorKind::Activation, TensorKind::Gradient];

    pub fn label(&self) -> &'static str {
        match self {
            TensorKind::Weight => "W",
            TensorKind::Activation => "X",
            TensorKind::Gradient => "dX",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn code_bounds_by_width() {
        for bits in BIT_STEPS {
            let s = Scheme::for_range(1.0, bits);
            assert_eq!(s.qmax(), (1i64 << (bits - 1)) - 1);
            assert_eq!(s.qmin(), -(1i64 << (bits - 1)));
        }
    }

    #[test]
    fn scale_covers_range() {
        // r*qmax >= Z for a spread of magnitudes and widths.
        for &z in &[1e-6f32, 0.3, 1.0, 77.0, 1e6] {
            for bits in BIT_STEPS {
                let s = Scheme::for_range(z, bits);
                assert!(
                    s.range_top() >= z * (1.0 - 1e-6),
                    "z={z} bits={bits} top={}",
                    s.range_top()
                );
            }
        }
    }

    #[test]
    fn zero_range_fallback() {
        let s = Scheme::for_range(0.0, 8);
        assert_eq!(s.s, -7);
        assert_eq!(s.fake_quant(0.0), 0.0);
    }

    #[test]
    fn non_finite_range_falls_back_like_zero() {
        // A NaN/Inf max-abs (dead layer, overflowed stat) must not poison
        // the scale: both take the zero-range fallback s = −(n−1), and the
        // resulting scheme stays fully usable on finite inputs.
        for z in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -3.0] {
            for bits in BIT_STEPS {
                let sch = Scheme::for_range(z, bits);
                assert_eq!(sch, Scheme::for_range(0.0, bits), "z={z} bits={bits}");
                assert!(sch.resolution().is_finite());
                assert_eq!(sch.fake_quant(0.5), {
                    let r = sch.resolution();
                    (0.5 / r).round_ties_even() * r
                });
            }
        }
    }

    #[test]
    fn non_finite_values_saturate_not_panic() {
        // Codes for non-finite *inputs* under a finite scheme: ±Inf clamp
        // to the end codes; NaN's clamp is well-defined in Rust (NaN.clamp
        // propagates NaN, `as i32` then saturates-to-0) — pin that it at
        // least stays in code range rather than UB-ing.
        let sch = Scheme::for_range(1.0, 8);
        assert_eq!(sch.code(f32::INFINITY) as i64, sch.qmax());
        assert_eq!(sch.code(f32::NEG_INFINITY) as i64, sch.qmin());
        let c = sch.code(f32::NAN) as i64;
        assert!(c >= sch.qmin() && c <= sch.qmax());
    }

    #[test]
    fn saturation() {
        let s = Scheme { bits: 8, s: 0 }; // r = 1
        assert_eq!(s.code(1000.0), 127);
        assert_eq!(s.code(-1000.0), -128);
        assert_eq!(s.fake_quant(1000.0), 127.0);
    }

    #[test]
    fn round_half_to_even_matches_numpy() {
        let s = Scheme { bits: 8, s: 0 };
        assert_eq!(s.code(0.5), 0); // numpy rounds 0.5 -> 0
        assert_eq!(s.code(1.5), 2);
        assert_eq!(s.code(2.5), 2);
        assert_eq!(s.code(-0.5), 0);
        assert_eq!(s.code(-1.5), -2);
    }

    #[test]
    fn prop_fake_quant_error_half_resolution() {
        check("fq-error-bound", 50, |g| {
            let bits = *g.choose(&[8u8, 16, 24]);
            let scale = g.f32_log(1e-4, 1e4);
            let xs = g.normal_vec(256, scale);
            let z = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
            let sch = Scheme::for_range(z, bits);
            for &x in &xs {
                let e = (x - sch.fake_quant(x)).abs();
                assert!(e <= sch.resolution() / 2.0 + 1e-9, "x={x} err={e} r={}", sch.resolution());
            }
        });
    }

    #[test]
    fn prop_idempotent() {
        check("fq-idempotent", 30, |g| {
            let bits = *g.choose(&[8u8, 16]);
            let _sc = g.f32_log(1e-2, 1e2);
            let xs = g.normal_vec(64, _sc);
            let z = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
            let sch = Scheme::for_range(z, bits);
            for &x in &xs {
                let q1 = sch.fake_quant(x);
                assert_eq!(q1, sch.fake_quant(q1));
            }
        });
    }

    #[test]
    fn prop_more_bits_never_worse() {
        check("bits-monotone", 30, |g| {
            let _sc = g.f32_log(1e-2, 1e2);
            let xs = g.normal_vec(256, _sc);
            let z = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
            let e8: f64 = xs
                .iter()
                .map(|&x| (x - Scheme::for_range(z, 8).fake_quant(x)).abs() as f64)
                .sum();
            let e16: f64 = xs
                .iter()
                .map(|&x| (x - Scheme::for_range(z, 16).fake_quant(x)).abs() as f64)
                .sum();
            assert!(e16 <= e8 + 1e-6, "e8={e8} e16={e16}");
        });
    }
}
