//! AVX-512 VNNI / BW integer GEMM kernels — the perf-pass hot path
//! (EXPERIMENTS.md §Perf).
//!
//! The paper's int8/int16 speedups come from wider integer SIMD lanes
//! (AVX2 on their Xeon 6154). The autovectorized broadcast-row kernels in
//! `gemm.rs` cannot beat f32 FMA: an i8 lane widened to i32 carries no more
//! MACs per instruction than f32. The dot-product layout does:
//!
//!   * int8:  `vpdpbusd` (AVX-512 VNNI) — 64 u8×s8 MACs per instruction.
//!     Signed×signed is handled with the classic bias trick:
//!     `(a ⊕ 0x80)·b = a·b + 128·b`, corrected by `128·Σ_k b[j,k]`
//!     (precomputed per output column during packing).
//!   * int16: `vpmaddwd` (AVX-512 BW) — 32 s16×s16 MACs per instruction.
//!
//! Both kernels consume B packed **transposed** (`bt[j*k ..]` contiguous in
//! k) so a whole K-panel streams through one accumulator register chain.
//! Runtime dispatch: callers go through [`super::gemm::gemm_i8`] /
//! [`super::gemm::gemm_i16`] (or the parallel `kernels::Engine`, which
//! shards row panels over the same kernels), picking these when the CPU
//! supports them.

#[cfg(target_arch = "x86_64")]
use core::arch::x86_64::*;

/// Pack row-major B (k×n) into BT (n×k) and per-column sums (for the i8
/// bias correction).
pub fn pack_bt_i8(k: usize, n: usize, b: &[i8], bt: &mut [i8], colsum: &mut [i32]) {
    assert_eq!(b.len(), k * n);
    assert_eq!(bt.len(), k * n);
    assert_eq!(colsum.len(), n);
    for j in 0..n {
        let mut s = 0i32;
        for p in 0..k {
            let v = b[p * n + j];
            bt[j * k + p] = v;
            s += v as i32;
        }
        colsum[j] = s;
    }
}

/// Pack row-major B (k×n) into BT (n×k) for the i16 kernel.
pub fn pack_bt_i16(k: usize, n: usize, b: &[i16], bt: &mut [i16]) {
    assert_eq!(b.len(), k * n);
    assert_eq!(bt.len(), k * n);
    for j in 0..n {
        for p in 0..k {
            bt[j * k + p] = b[p * n + j];
        }
    }
}

/// Is the VNNI path available on this CPU?
pub fn has_vnni() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512vnni") && is_x86_feature_detected!("avx512bw")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Is the AVX-512 BW (vpmaddwd) path available?
pub fn has_avx512bw() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx512bw") && is_x86_feature_detected!("avx512f")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Backend selection for i8 GEMM: the VNNI kernel pays off once a full
/// 64-lane register chain fits in k. Shared by the serial dispatch and the
/// parallel `kernels::Engine` so the two can never diverge.
pub fn use_vnni_i8(k: usize) -> bool {
    has_vnni() && k >= 64
}

/// Backend selection for i16 GEMM (32-lane vpmaddwd chain); see
/// [`use_vnni_i8`].
pub fn use_madd_i16(k: usize) -> bool {
    has_avx512bw() && k >= 32
}

/// Unpack BT (n×k) back to row-major B (k×n) — the off-AVX512 fallback of
/// the prepacked entry points.
pub fn unpack_bt_i8(k: usize, n: usize, bt: &[i8]) -> Vec<i8> {
    assert_eq!(bt.len(), k * n);
    let mut b = vec![0i8; k * n];
    for j in 0..n {
        for p in 0..k {
            b[p * n + j] = bt[j * k + p];
        }
    }
    b
}

/// i16 variant of [`unpack_bt_i8`].
pub fn unpack_bt_i16(k: usize, n: usize, bt: &[i16]) -> Vec<i16> {
    assert_eq!(bt.len(), k * n);
    let mut b = vec![0i16; k * n];
    for j in 0..n {
        for p in 0..k {
            b[p * n + j] = bt[j * k + p];
        }
    }
    b
}

/// i8 GEMM on pre-packed BT: c[i,j] = Σ_k a[i,k]·bt[j,k], i32 accumulate.
///
/// # Safety
/// Requires avx512f+avx512bw+avx512vnni (check [`has_vnni`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw,avx512vnni")]
pub unsafe fn gemm_i8_vnni_packed(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    bt: &[i8],
    colsum: &[i32],
    c: &mut [i32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let bias = _mm512_set1_epi8(-128i8 as i8); // XOR 0x80 == add 128 (u8 view)
    let kv = k / 64 * 64;
    // j-outer: BT (the big panel) streams exactly once; the whole A block
    // stays cache-resident and is reused for every output column.
    for j in 0..n {
        let brow = &bt[j * k..(j + 1) * k];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = _mm512_setzero_si512();
            let mut p = 0usize;
            while p < kv {
                let av = _mm512_loadu_si512(arow.as_ptr().add(p) as *const _);
                let au = _mm512_xor_si512(av, bias); // a + 128 as u8
                let bv = _mm512_loadu_si512(brow.as_ptr().add(p) as *const _);
                acc = _mm512_dpbusd_epi32(acc, au, bv);
                p += 64;
            }
            let mut sum = _mm512_reduce_add_epi32(acc);
            // scalar tail
            let mut tail_bsum = 0i32;
            while p < k {
                sum += (arow[p] as i32 + 128) * brow[p] as i32;
                tail_bsum += brow[p] as i32;
                p += 1;
            }
            let _ = tail_bsum; // tail already used the biased product
            // correction: subtract 128·Σ_k b — colsum covers the FULL k
            sum -= 128 * colsum[j];
            c[i * n + j] = sum;
        }
    }
}

/// i16 GEMM on pre-packed BT via vpmaddwd.
///
/// # Safety
/// Requires avx512f+avx512bw (check [`has_avx512bw`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f,avx512bw")]
pub unsafe fn gemm_i16_madd_packed(
    m: usize,
    k: usize,
    n: usize,
    a: &[i16],
    bt: &[i16],
    c: &mut [i32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(bt.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let kv = k / 32 * 32;
    // j-outer: see gemm_i8_vnni_packed — stream BT once, keep A hot.
    for j in 0..n {
        let brow = &bt[j * k..(j + 1) * k];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let mut acc = _mm512_setzero_si512();
            let mut p = 0usize;
            while p < kv {
                let av = _mm512_loadu_si512(arow.as_ptr().add(p) as *const _);
                let bv = _mm512_loadu_si512(brow.as_ptr().add(p) as *const _);
                acc = _mm512_add_epi32(acc, _mm512_madd_epi16(av, bv));
                p += 32;
            }
            let mut sum = _mm512_reduce_add_epi32(acc);
            while p < k {
                sum += arow[p] as i32 * brow[p] as i32;
                p += 1;
            }
            c[i * n + j] = sum;
        }
    }
}

/// Safe wrapper: i8 GEMM with row-major B (packs internally). Falls back to
/// the portable kernel when VNNI is unavailable.
pub fn gemm_i8_fast(m: usize, k: usize, n: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    if use_vnni_i8(k) {
        let mut bt = vec![0i8; k * n];
        let mut colsum = vec![0i32; n];
        pack_bt_i8(k, n, b, &mut bt, &mut colsum);
        unsafe {
            gemm_i8_vnni_packed(m, k, n, a, &bt, &colsum, c);
        }
        return;
    }
    super::gemm::gemm_i8_portable(m, k, n, a, b, c);
}

/// Safe wrapper: i16 GEMM with row-major B (packs internally).
pub fn gemm_i16_fast(m: usize, k: usize, n: usize, a: &[i16], b: &[i16], c: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    if use_madd_i16(k) {
        let mut bt = vec![0i16; k * n];
        pack_bt_i16(k, n, b, &mut bt);
        unsafe {
            gemm_i16_madd_packed(m, k, n, a, &bt, c);
        }
        return;
    }
    super::gemm::gemm_i16_portable(m, k, n, a, b, c);
}


/// Safe prepacked entry points: in training, quantization emits codes
/// directly in BT layout (one pass, same cost as row-major emission), so
/// the GEMM itself is what Table 3 times. Falls back to repacking + the
/// portable kernel off-AVX512.
pub fn gemm_i8_prepacked(
    m: usize,
    k: usize,
    n: usize,
    a: &[i8],
    bt: &[i8],
    colsum: &[i32],
    c: &mut [i32],
) {
    #[cfg(target_arch = "x86_64")]
    if has_vnni() {
        unsafe {
            gemm_i8_vnni_packed(m, k, n, a, bt, colsum, c);
        }
        return;
    }
    // unpack and use the portable kernel
    let b = unpack_bt_i8(k, n, bt);
    super::gemm::gemm_i8_portable(m, k, n, a, &b, c);
}

/// Prepacked i16 GEMM (see [`gemm_i8_prepacked`]).
pub fn gemm_i16_prepacked(m: usize, k: usize, n: usize, a: &[i16], bt: &[i16], c: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    if has_avx512bw() {
        unsafe {
            gemm_i16_madd_packed(m, k, n, a, bt, c);
        }
        return;
    }
    let b = unpack_bt_i16(k, n, bt);
    super::gemm::gemm_i16_portable(m, k, n, a, &b, c);
}

/// Quantize f32 row-major (k×n) directly into BT codes + column sums — the
/// single fused pass the training loop uses (no separate transpose).
pub fn codes_i8_bt(
    k: usize,
    n: usize,
    src: &[f32],
    sch: crate::fixedpoint::Scheme,
    bt: &mut [i8],
    colsum: &mut [i32],
) {
    assert_eq!(src.len(), k * n);
    assert_eq!(bt.len(), k * n);
    assert_eq!(colsum.len(), n);
    let inv_r = 1.0 / sch.resolution();
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    colsum.fill(0);
    for p in 0..k {
        let row = &src[p * n..(p + 1) * n];
        for (j, &x) in row.iter().enumerate() {
            let code = (x * inv_r).round_ties_even().clamp(lo, hi) as i8;
            bt[j * k + p] = code;
            colsum[j] += code as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive_i8(m: usize, k: usize, n: usize, a: &[i8], b: &[i8]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] as i32 * b[p * n + j] as i32;
                }
            }
        }
        c
    }

    fn naive_i16(m: usize, k: usize, n: usize, a: &[i16], b: &[i16]) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] as i32 * b[p * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn i8_fast_exact_including_tails() {
        let mut rng = Pcg32::seeded(1);
        for &(m, k, n) in &[(3usize, 64usize, 5usize), (7, 100, 9), (16, 192, 16), (1, 65, 1)] {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut c = vec![0i32; m * n];
            gemm_i8_fast(m, k, n, &a, &b, &mut c);
            assert_eq!(c, naive_i8(m, k, n, &a, &b), "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn i8_fast_extreme_values() {
        // saturating corners: -128 everywhere (the bias trick's edge)
        let (m, k, n) = (2usize, 64usize, 2usize);
        let a = vec![-128i8; m * k];
        let b = vec![-128i8; k * n];
        let mut c = vec![0i32; m * n];
        gemm_i8_fast(m, k, n, &a, &b, &mut c);
        assert!(c.iter().all(|&v| v == 64 * 128 * 128));
    }

    #[test]
    fn i16_fast_exact_including_tails() {
        let mut rng = Pcg32::seeded(2);
        for &(m, k, n) in &[(3usize, 32usize, 5usize), (5, 100, 7), (8, 96, 8), (1, 33, 1)] {
            let a: Vec<i16> = (0..m * k).map(|_| (rng.below(65535) as i32 - 32767) as i16).collect();
            let b: Vec<i16> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i16).collect();
            let mut c = vec![0i32; m * n];
            gemm_i16_fast(m, k, n, &a, &b, &mut c);
            assert_eq!(c, naive_i16(m, k, n, &a, &b), "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn prepacked_matches_fast() {
        let mut rng = Pcg32::seeded(3);
        let (m, k, n) = (4usize, 96usize, 6usize);
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let mut bt = vec![0i8; k * n];
        let mut colsum = vec![0i32; n];
        pack_bt_i8(k, n, &b, &mut bt, &mut colsum);
        let mut c1 = vec![0i32; m * n];
        let mut c2 = vec![0i32; m * n];
        gemm_i8_prepacked(m, k, n, &a, &bt, &colsum, &mut c1);
        gemm_i8_fast(m, k, n, &a, &b, &mut c2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn codes_bt_fused_pass_matches_two_pass() {
        use crate::fixedpoint::quantize::{codes_i8, max_abs};
        use crate::fixedpoint::Scheme;
        let mut rng = Pcg32::seeded(4);
        let (k, n) = (64usize, 8usize);
        let src: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let sch = Scheme::for_range(max_abs(&src), 8);
        let mut bt = vec![0i8; k * n];
        let mut colsum = vec![0i32; n];
        codes_i8_bt(k, n, &src, sch, &mut bt, &mut colsum);
        let mut codes = vec![0i8; k * n];
        codes_i8(&src, &mut codes, sch);
        for j in 0..n {
            let mut s = 0i32;
            for p in 0..k {
                assert_eq!(bt[j * k + p], codes[p * n + j]);
                s += codes[p * n + j] as i32;
            }
            assert_eq!(colsum[j], s);
        }
    }

    #[test]
    fn small_k_falls_back_to_portable() {
        let (m, k, n) = (4usize, 8usize, 4usize);
        let a = vec![1i8; m * k];
        let b = vec![2i8; k * n];
        let mut c = vec![0i32; m * n];
        gemm_i8_fast(m, k, n, &a, &b, &mut c);
        assert!(c.iter().all(|&v| v == 16));
    }
}
