//! Convolution via im2col + GEMM, in f32 and fixed-point variants.
//!
//! Layout: NCHW activations, OIHW weights, row-major. im2col lowers a
//! convolution to a `(C·KH·KW) × (OH·OW)` patch matrix per image so all
//! conv speed/accuracy questions reduce to the GEMM kernels in `gemm.rs` —
//! exactly how the paper's CPU implementation (and MKL-DNN) works, which is
//! what makes the Table 3 / Fig 10 layer-shape benchmarks faithful. The
//! im2col GEMM's `m` is `out_c`, so the engine's row-panel sharding gives
//! conv its output-channel-block parallelism (DESIGN.md §Kernel-Engine).

use super::gemm;

/// Convolution geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dGeom {
    pub in_c: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dGeom {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// Multiply-accumulate count for a forward pass over one image.
    pub fn macs(&self, h: usize, w: usize) -> u64 {
        let (oh, ow) = self.out_hw(h, w);
        (self.out_c * oh * ow) as u64 * (self.in_c * self.kh * self.kw) as u64
    }

    /// im2col patch-matrix dims for one image: (rows = C·KH·KW, cols = OH·OW).
    pub fn im2col_dims(&self, h: usize, w: usize) -> (usize, usize) {
        let (oh, ow) = self.out_hw(h, w);
        (self.in_c * self.kh * self.kw, oh * ow)
    }
}

/// Lower one image (C×H×W) into the im2col patch matrix (row-major
/// rows=C·KH·KW, cols=OH·OW). `out` must be sized `rows*cols`.
pub fn im2col(g: Conv2dGeom, h: usize, w: usize, img: &[f32], out: &mut [f32]) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(img.len(), g.in_c * h * w);
    assert_eq!(out.len(), rows * cols);
    let mut row = 0usize;
    for c in 0..g.in_c {
        for ky in 0..g.kh {
            for kx in 0..g.kw {
                let orow = &mut out[row * cols..(row + 1) * cols];
                let mut col = 0usize;
                for oy in 0..oh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for ox in 0..ow {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        orow[col] = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            img[c * h * w + iy as usize * w + ix as usize]
                        } else {
                            0.0
                        };
                        col += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// Scatter-add the transpose of im2col (col2im) — the backward of `im2col`,
/// used by BPROP to push patch-space gradients back to image space.
pub fn col2im(g: Conv2dGeom, h: usize, w: usize, cols_mat: &[f32], img_grad: &mut [f32]) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(cols_mat.len(), rows * cols);
    assert_eq!(img_grad.len(), g.in_c * h * w);
    img_grad.fill(0.0);
    let mut row = 0usize;
    for c in 0..g.in_c {
        for ky in 0..g.kh {
            for kx in 0..g.kw {
                let crow = &cols_mat[row * cols..(row + 1) * cols];
                let mut col = 0usize;
                for oy in 0..oh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for ox in 0..ow {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            img_grad[c * h * w + iy as usize * w + ix as usize] += crow[col];
                        }
                        col += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// f32 forward convolution of one image: out[out_c × OH·OW] = W · im2col(x).
/// `scratch` must hold `rows*cols` f32.
pub fn conv2d_f32(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[f32],
    weight: &[f32], // out_c × (in_c·kh·kw)
    out: &mut [f32],
    scratch: &mut [f32],
) {
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(weight.len(), g.out_c * rows);
    assert_eq!(out.len(), g.out_c * cols);
    im2col(g, h, w, img, scratch);
    gemm::gemm_f32(g.out_c, rows, cols, weight, scratch, out);
}

/// Quantized forward convolution (codes + integer GEMM + rescale); used by
/// the Table 3 / Fig 10 benches. i8 path.
pub fn conv2d_i8(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[f32],
    s_img: super::Scheme,
    weight: &[f32],
    s_w: super::Scheme,
    out: &mut [f32],
) {
    let (rows, cols) = g.im2col_dims(h, w);
    let mut patch = vec![0.0f32; rows * cols];
    im2col(g, h, w, img, &mut patch);
    let mut cw = vec![0i8; weight.len()];
    let mut cp = vec![0i8; patch.len()];
    super::quantize::codes_i8(weight, &mut cw, s_w);
    super::quantize::codes_i8(&patch, &mut cp, s_img);
    let mut acc = vec![0i32; out.len()];
    gemm::gemm_i8(g.out_c, rows, cols, &cw, &cp, &mut acc);
    gemm::rescale_i32(&acc, s_w.resolution() * s_img.resolution(), out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::max_abs;
    use crate::fixedpoint::Scheme;
    use crate::util::Pcg32;

    fn naive_conv(
        g: Conv2dGeom,
        h: usize,
        w: usize,
        img: &[f32],
        weight: &[f32],
    ) -> Vec<f32> {
        let (oh, ow) = g.out_hw(h, w);
        let mut out = vec![0.0f32; g.out_c * oh * ow];
        for oc in 0..g.out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for c in 0..g.in_c {
                        for ky in 0..g.kh {
                            for kx in 0..g.kw {
                                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                                let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                                if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                                    let iv = img[c * h * w + iy as usize * w + ix as usize];
                                    let wv = weight
                                        [oc * g.in_c * g.kh * g.kw + c * g.kh * g.kw + ky * g.kw + kx];
                                    acc += iv * wv;
                                }
                            }
                        }
                    }
                    out[oc * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
        out
    }

    fn geom() -> Conv2dGeom {
        Conv2dGeom { in_c: 3, out_c: 5, kh: 3, kw: 3, stride: 2, pad: 1 }
    }

    #[test]
    fn conv_matches_naive() {
        let g = geom();
        let (h, w) = (11, 9);
        let mut r = Pcg32::seeded(1);
        let img: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
        let weight: Vec<f32> = (0..g.out_c * g.in_c * g.kh * g.kw).map(|_| r.normal()).collect();
        let (rows, cols) = g.im2col_dims(h, w);
        let mut out = vec![0.0; g.out_c * cols];
        let mut scratch = vec![0.0; rows * cols];
        conv2d_f32(g, h, w, &img, &weight, &mut out, &mut scratch);
        let want = naive_conv(g, h, w, &img, &weight);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn quantized_conv_close_to_f32_at_int8() {
        let g = geom();
        let (h, w) = (8, 8);
        let mut r = Pcg32::seeded(2);
        let img: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
        let weight: Vec<f32> = (0..g.out_c * g.in_c * g.kh * g.kw).map(|_| r.normal() * 0.2).collect();
        let (_, cols) = g.im2col_dims(h, w);
        let mut qout = vec![0.0; g.out_c * cols];
        conv2d_i8(
            g, h, w,
            &img, Scheme::for_range(max_abs(&img), 8),
            &weight, Scheme::for_range(max_abs(&weight), 8),
            &mut qout,
        );
        let want = naive_conv(g, h, w, &img, &weight);
        let err: f32 = qout.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum::<f32>()
            / want.iter().map(|v| v.abs()).sum::<f32>();
        assert!(err < 0.05, "relative int8 conv error {err}");
    }

    #[test]
    fn col2im_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> — adjointness (the property BPROP
        // relies on).
        let g = geom();
        let (h, w) = (7, 6);
        let mut r = Pcg32::seeded(3);
        let x: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
        let (rows, cols) = g.im2col_dims(h, w);
        let y: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let mut ix = vec![0.0; rows * cols];
        im2col(g, h, w, &x, &mut ix);
        let lhs: f64 = ix.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
        let mut cy = vec![0.0; x.len()];
        col2im(g, h, w, &y, &mut cy);
        let rhs: f64 = x.iter().zip(&cy).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn geometry_math() {
        let g = Conv2dGeom { in_c: 3, out_c: 96, kh: 11, kw: 11, stride: 4, pad: 0 };
        // AlexNet conv0 on 227×227 → 55×55
        assert_eq!(g.out_hw(227, 227), (55, 55));
        assert_eq!(g.macs(227, 227), 96 * 55 * 55 * 3 * 11 * 11);
    }
}
