//! Convolution via im2col + GEMM, in f32 and fixed-point variants.
//!
//! Layout: NCHW activations, OIHW weights, row-major. im2col lowers a
//! convolution to a `(C·KH·KW) × (OH·OW)` patch matrix per image so all
//! conv speed/accuracy questions reduce to the GEMM kernels in `gemm.rs` —
//! exactly how the paper's CPU implementation (and MKL-DNN) works, which is
//! what makes the Table 3 / Fig 10 layer-shape benchmarks faithful. The
//! im2col GEMM's `m` is `out_c`, so the engine's row-panel sharding gives
//! conv its output-channel-block parallelism (DESIGN.md §Kernel-Engine).

use super::gemm;

/// Convolution geometry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Conv2dGeom {
    pub in_c: usize,
    pub out_c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2dGeom {
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.kh) / self.stride + 1,
            (w + 2 * self.pad - self.kw) / self.stride + 1,
        )
    }

    /// Multiply-accumulate count for a forward pass over one image.
    pub fn macs(&self, h: usize, w: usize) -> u64 {
        let (oh, ow) = self.out_hw(h, w);
        (self.out_c * oh * ow) as u64 * (self.in_c * self.kh * self.kw) as u64
    }

    /// im2col patch-matrix dims for one image: (rows = C·KH·KW, cols = OH·OW).
    pub fn im2col_dims(&self, h: usize, w: usize) -> (usize, usize) {
        let (oh, ow) = self.out_hw(h, w);
        (self.in_c * self.kh * self.kw, oh * ow)
    }
}

/// Lower one image (C×H×W) into the im2col patch matrix (row-major
/// rows=C·KH·KW, cols=OH·OW). `out` must be sized `rows*cols`.
pub fn im2col(g: Conv2dGeom, h: usize, w: usize, img: &[f32], out: &mut [f32]) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(img.len(), g.in_c * h * w);
    assert_eq!(out.len(), rows * cols);
    let mut row = 0usize;
    for c in 0..g.in_c {
        for ky in 0..g.kh {
            for kx in 0..g.kw {
                let orow = &mut out[row * cols..(row + 1) * cols];
                let mut col = 0usize;
                for oy in 0..oh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for ox in 0..ow {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        orow[col] = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            img[c * h * w + iy as usize * w + ix as usize]
                        } else {
                            0.0
                        };
                        col += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// im2col fused with int8 quantization straight into the BT (column-major)
/// GEMM layout: `bt[col·rows + row]` holds the code of patch element
/// `(row, col)`, and `colsum[col]` the column's code sum (the VNNI unsigned
/// bias correction — see `gemm_simd::pack_bt_i8`). One pass replaces the
/// serve hot path's im2col → `codes_i8` → `pack_bt_i8` chain (three sweeps
/// + two temporaries); per-element results are bit-identical because the
/// scalar quantize is the same expression, padding quantizes 0.0 → code 0,
/// and the element order never feeds back into the values.
pub fn im2col_bt_quant_i8(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[f32],
    sch: super::Scheme,
    bt: &mut [i8],
    colsum: &mut [i32],
) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(img.len(), g.in_c * h * w);
    assert_eq!(bt.len(), rows * cols);
    assert_eq!(colsum.len(), cols);
    let inv_r = 1.0 / sch.resolution();
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    // Column-outer: each output position gathers its patch contiguously
    // into one BT column (unit-stride writes, unlike transposing im2col's
    // row-major output).
    let mut col = 0usize;
    for oy in 0..oh {
        for ox in 0..ow {
            let bcol = &mut bt[col * rows..(col + 1) * rows];
            let mut sum = 0i32;
            let mut row = 0usize;
            for c in 0..g.in_c {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        let q = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            let x = img[c * h * w + iy as usize * w + ix as usize];
                            (x * inv_r).round_ties_even().clamp(lo, hi) as i8
                        } else {
                            0
                        };
                        bcol[row] = q;
                        sum += q as i32;
                        row += 1;
                    }
                }
            }
            colsum[col] = sum;
            col += 1;
        }
    }
}

/// int16 sibling of [`im2col_bt_quant_i8`] (no column sums — the
/// `vpmaddwd` kernel multiplies signed operands directly).
pub fn im2col_bt_quant_i16(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[f32],
    sch: super::Scheme,
    bt: &mut [i16],
) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(img.len(), g.in_c * h * w);
    assert_eq!(bt.len(), rows * cols);
    let inv_r = 1.0 / sch.resolution();
    let lo = sch.qmin() as f32;
    let hi = sch.qmax() as f32;
    let mut col = 0usize;
    for oy in 0..oh {
        for ox in 0..ow {
            let bcol = &mut bt[col * rows..(col + 1) * rows];
            let mut row = 0usize;
            for c in 0..g.in_c {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        bcol[row] = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            let x = img[c * h * w + iy as usize * w + ix as usize];
                            (x * inv_r).round_ties_even().clamp(lo, hi) as i16
                        } else {
                            0
                        };
                        row += 1;
                    }
                }
            }
            col += 1;
        }
    }
}

/// im2col over an image that is *already* int8 codes, gathered straight
/// into the BT layout: the fused-execution path where a producer op emitted
/// integer codes and the consumer conv never sees f32 at all
/// (DESIGN.md §Inference-Compiler). Padding contributes code 0 — exactly
/// what quantizing a 0.0 pad yields.
pub fn im2col_bt_codes_i8(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[i8],
    bt: &mut [i8],
    colsum: &mut [i32],
) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(img.len(), g.in_c * h * w);
    assert_eq!(bt.len(), rows * cols);
    assert_eq!(colsum.len(), cols);
    let mut col = 0usize;
    for oy in 0..oh {
        for ox in 0..ow {
            let bcol = &mut bt[col * rows..(col + 1) * rows];
            let mut sum = 0i32;
            let mut row = 0usize;
            for c in 0..g.in_c {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        let q = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            img[c * h * w + iy as usize * w + ix as usize]
                        } else {
                            0
                        };
                        bcol[row] = q;
                        sum += q as i32;
                        row += 1;
                    }
                }
            }
            colsum[col] = sum;
            col += 1;
        }
    }
}

/// int16 sibling of [`im2col_bt_codes_i8`].
pub fn im2col_bt_codes_i16(g: Conv2dGeom, h: usize, w: usize, img: &[i16], bt: &mut [i16]) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(img.len(), g.in_c * h * w);
    assert_eq!(bt.len(), rows * cols);
    let mut col = 0usize;
    for oy in 0..oh {
        for ox in 0..ow {
            let bcol = &mut bt[col * rows..(col + 1) * rows];
            let mut row = 0usize;
            for c in 0..g.in_c {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        bcol[row] = if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            img[c * h * w + iy as usize * w + ix as usize]
                        } else {
                            0
                        };
                        row += 1;
                    }
                }
            }
            col += 1;
        }
    }
}

/// Scatter-add the transpose of im2col (col2im) — the backward of `im2col`,
/// used by BPROP to push patch-space gradients back to image space.
pub fn col2im(g: Conv2dGeom, h: usize, w: usize, cols_mat: &[f32], img_grad: &mut [f32]) {
    let (oh, ow) = g.out_hw(h, w);
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(cols_mat.len(), rows * cols);
    assert_eq!(img_grad.len(), g.in_c * h * w);
    img_grad.fill(0.0);
    let mut row = 0usize;
    for c in 0..g.in_c {
        for ky in 0..g.kh {
            for kx in 0..g.kw {
                let crow = &cols_mat[row * cols..(row + 1) * cols];
                let mut col = 0usize;
                for oy in 0..oh {
                    let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                    for ox in 0..ow {
                        let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                        if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                            img_grad[c * h * w + iy as usize * w + ix as usize] += crow[col];
                        }
                        col += 1;
                    }
                }
                row += 1;
            }
        }
    }
}

/// f32 forward convolution of one image: out[out_c × OH·OW] = W · im2col(x).
/// `scratch` must hold `rows*cols` f32.
pub fn conv2d_f32(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[f32],
    weight: &[f32], // out_c × (in_c·kh·kw)
    out: &mut [f32],
    scratch: &mut [f32],
) {
    let (rows, cols) = g.im2col_dims(h, w);
    assert_eq!(weight.len(), g.out_c * rows);
    assert_eq!(out.len(), g.out_c * cols);
    im2col(g, h, w, img, scratch);
    gemm::gemm_f32(g.out_c, rows, cols, weight, scratch, out);
}

/// Quantized forward convolution (codes + integer GEMM + rescale); used by
/// the Table 3 / Fig 10 benches. i8 path.
pub fn conv2d_i8(
    g: Conv2dGeom,
    h: usize,
    w: usize,
    img: &[f32],
    s_img: super::Scheme,
    weight: &[f32],
    s_w: super::Scheme,
    out: &mut [f32],
) {
    let (rows, cols) = g.im2col_dims(h, w);
    let mut patch = vec![0.0f32; rows * cols];
    im2col(g, h, w, img, &mut patch);
    let mut cw = vec![0i8; weight.len()];
    let mut cp = vec![0i8; patch.len()];
    super::quantize::codes_i8(weight, &mut cw, s_w);
    super::quantize::codes_i8(&patch, &mut cp, s_img);
    let mut acc = vec![0i32; out.len()];
    gemm::gemm_i8(g.out_c, rows, cols, &cw, &cp, &mut acc);
    gemm::rescale_i32(&acc, s_w.resolution() * s_img.resolution(), out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixedpoint::quantize::max_abs;
    use crate::fixedpoint::Scheme;
    use crate::util::Pcg32;

    fn naive_conv(
        g: Conv2dGeom,
        h: usize,
        w: usize,
        img: &[f32],
        weight: &[f32],
    ) -> Vec<f32> {
        let (oh, ow) = g.out_hw(h, w);
        let mut out = vec![0.0f32; g.out_c * oh * ow];
        for oc in 0..g.out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for c in 0..g.in_c {
                        for ky in 0..g.kh {
                            for kx in 0..g.kw {
                                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                                let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                                if iy >= 0 && iy < h as isize && ix >= 0 && ix < w as isize {
                                    let iv = img[c * h * w + iy as usize * w + ix as usize];
                                    let wv = weight
                                        [oc * g.in_c * g.kh * g.kw + c * g.kh * g.kw + ky * g.kw + kx];
                                    acc += iv * wv;
                                }
                            }
                        }
                    }
                    out[oc * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
        out
    }

    fn geom() -> Conv2dGeom {
        Conv2dGeom { in_c: 3, out_c: 5, kh: 3, kw: 3, stride: 2, pad: 1 }
    }

    #[test]
    fn conv_matches_naive() {
        let g = geom();
        let (h, w) = (11, 9);
        let mut r = Pcg32::seeded(1);
        let img: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
        let weight: Vec<f32> = (0..g.out_c * g.in_c * g.kh * g.kw).map(|_| r.normal()).collect();
        let (rows, cols) = g.im2col_dims(h, w);
        let mut out = vec![0.0; g.out_c * cols];
        let mut scratch = vec![0.0; rows * cols];
        conv2d_f32(g, h, w, &img, &weight, &mut out, &mut scratch);
        let want = naive_conv(g, h, w, &img, &weight);
        for (x, y) in out.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn quantized_conv_close_to_f32_at_int8() {
        let g = geom();
        let (h, w) = (8, 8);
        let mut r = Pcg32::seeded(2);
        let img: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
        let weight: Vec<f32> = (0..g.out_c * g.in_c * g.kh * g.kw).map(|_| r.normal() * 0.2).collect();
        let (_, cols) = g.im2col_dims(h, w);
        let mut qout = vec![0.0; g.out_c * cols];
        conv2d_i8(
            g, h, w,
            &img, Scheme::for_range(max_abs(&img), 8),
            &weight, Scheme::for_range(max_abs(&weight), 8),
            &mut qout,
        );
        let want = naive_conv(g, h, w, &img, &weight);
        let err: f32 = qout.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum::<f32>()
            / want.iter().map(|v| v.abs()).sum::<f32>();
        assert!(err < 0.05, "relative int8 conv error {err}");
    }

    #[test]
    fn im2col_bt_quant_matches_two_pass() {
        // Fused gather+quantize+BT-pack must be bit-identical to
        // im2col → codes → pack_bt (the route it replaces in serving).
        use crate::fixedpoint::gemm_simd::{pack_bt_i16, pack_bt_i8};
        use crate::fixedpoint::quantize::{codes_i16, codes_i8};
        for &(g, h, w) in &[
            (geom(), 11, 9),
            (Conv2dGeom { in_c: 1, out_c: 2, kh: 3, kw: 3, stride: 1, pad: 1 }, 5, 4),
            (Conv2dGeom { in_c: 4, out_c: 3, kh: 5, kw: 5, stride: 2, pad: 2 }, 12, 12),
        ] {
            let mut r = Pcg32::seeded(31);
            let img: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
            let sch = Scheme::for_range(max_abs(&img), 8);
            let (rows, cols) = g.im2col_dims(h, w);

            let mut patch = vec![0.0f32; rows * cols];
            im2col(g, h, w, &img, &mut patch);
            let mut pc8 = vec![0i8; rows * cols];
            codes_i8(&patch, &mut pc8, sch);
            let mut want_bt = vec![0i8; rows * cols];
            let mut want_cs = vec![0i32; cols];
            pack_bt_i8(rows, cols, &pc8, &mut want_bt, &mut want_cs);

            let mut bt = vec![0i8; rows * cols];
            let mut cs = vec![0i32; cols];
            im2col_bt_quant_i8(g, h, w, &img, sch, &mut bt, &mut cs);
            assert_eq!(bt, want_bt);
            assert_eq!(cs, want_cs);

            // codes-input gather: quantize image first, then gather.
            let mut ci = vec![0i8; img.len()];
            codes_i8(&img, &mut ci, sch);
            let mut bt2 = vec![0i8; rows * cols];
            let mut cs2 = vec![0i32; cols];
            im2col_bt_codes_i8(g, h, w, &ci, &mut bt2, &mut cs2);
            // gather-of-codes == quantize-of-gather: im2col only copies
            // (and pads with 0.0 → code 0), so the two commute exactly.
            assert_eq!(bt2, want_bt);
            assert_eq!(cs2, want_cs);

            let s16 = Scheme::for_range(max_abs(&img), 16);
            let mut pc16 = vec![0i16; rows * cols];
            codes_i16(&patch, &mut pc16, s16);
            let mut want16 = vec![0i16; rows * cols];
            pack_bt_i16(rows, cols, &pc16, &mut want16);
            let mut bt16 = vec![0i16; rows * cols];
            im2col_bt_quant_i16(g, h, w, &img, s16, &mut bt16);
            assert_eq!(bt16, want16);

            let mut ci16 = vec![0i16; img.len()];
            codes_i16(&img, &mut ci16, s16);
            let mut bt16b = vec![0i16; rows * cols];
            im2col_bt_codes_i16(g, h, w, &ci16, &mut bt16b);
            assert_eq!(bt16b, want16);
        }
    }

    #[test]
    fn col2im_adjoint_of_im2col() {
        // <im2col(x), y> == <x, col2im(y)> — adjointness (the property BPROP
        // relies on).
        let g = geom();
        let (h, w) = (7, 6);
        let mut r = Pcg32::seeded(3);
        let x: Vec<f32> = (0..g.in_c * h * w).map(|_| r.normal()).collect();
        let (rows, cols) = g.im2col_dims(h, w);
        let y: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let mut ix = vec![0.0; rows * cols];
        im2col(g, h, w, &x, &mut ix);
        let lhs: f64 = ix.iter().zip(&y).map(|(a, b)| (a * b) as f64).sum();
        let mut cy = vec![0.0; x.len()];
        col2im(g, h, w, &y, &mut cy);
        let rhs: f64 = x.iter().zip(&cy).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn geometry_math() {
        let g = Conv2dGeom { in_c: 3, out_c: 96, kh: 11, kw: 11, stride: 4, pad: 0 };
        // AlexNet conv0 on 227×227 → 55×55
        assert_eq!(g.out_hw(227, 227), (55, 55));
        assert_eq!(g.macs(227, 227), 96 * 55 * 55 * 3 * 11 * 11);
    }
}
