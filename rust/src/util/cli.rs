//! Minimal CLI argument parser (offline substitute for `clap`).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Convention: positionals come *before* options (a bare token
//! after `--flag` is consumed as that flag's value — `--key value` wins the
//! ambiguity, matching how all `apt` subcommands are invoked).

use std::collections::BTreeMap;

/// Parsed arguments: options + positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut opts = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    opts.insert(body.to_string(), v);
                } else {
                    opts.insert(body.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Args { opts, positional }
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, key: &str) -> bool {
        self.opts.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a bool, got {v:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_forms() {
        let a = parse("run fig1 --iters 100 --lr=0.05 --verbose");
        assert_eq!(a.usize_or("iters", 0), 100);
        assert!((a.f32_or("lr", 0.0) - 0.05).abs() < 1e-9);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional(), &["run".to_string(), "fig1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("cmd");
        assert_eq!(a.usize_or("iters", 7), 7);
        assert_eq!(a.str_or("mode", "mode2"), "mode2");
        assert!(!a.bool_or("verbose", false));
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse("--offset -3");
        // "-3" does not start with "--" so it is consumed as the value.
        assert_eq!(a.get("offset"), Some("-3"));
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        parse("--iters abc").usize_or("iters", 0);
    }
}
