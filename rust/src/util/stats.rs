//! Small statistics toolkit: summaries, Pearson correlation, histograms.
//!
//! Used by the experiment drivers (Fig 1/2 histograms, Fig 5/6 R² scores)
//! and by the bench harness for timing summaries.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median — the 50th [`percentile`] (linear interpolation reproduces the
/// classic even-length midpoint).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linearly interpolated percentile (`q` in [0, 100]) — the p50/p99
/// summary the serving benches report (EXPERIMENTS.md §Serve).
///
/// NaN-tolerant: samples are ordered with [`f64::total_cmp`], which gives
/// NaNs a deterministic position (positive NaNs sort above +∞) instead of
/// panicking mid-sort — a single NaN latency sample used to abort the
/// whole serve bench via `partial_cmp(..).unwrap()`. NaNs therefore only
/// influence the extreme percentiles; callers wanting them excluded
/// entirely should filter with `is_finite` first.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (q.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Squared Pearson correlation coefficient — paper Eq. 4:
/// `R² = (Σ(M−M̄)(a−ā))² / (Σ(M−M̄)² Σ(a−ā)²)`.
pub fn pearson_r2(m: &[f64], a: &[f64]) -> f64 {
    assert_eq!(m.len(), a.len(), "series must align");
    if m.len() < 2 {
        return 0.0;
    }
    let mm = mean(m);
    let ma = mean(a);
    let mut cov = 0.0;
    let mut vm = 0.0;
    let mut va = 0.0;
    for (x, y) in m.iter().zip(a) {
        cov += (x - mm) * (y - ma);
        vm += (x - mm) * (x - mm);
        va += (y - ma) * (y - ma);
    }
    if vm == 0.0 || va == 0.0 {
        return 0.0;
    }
    (cov * cov) / (vm * va)
}

/// Fixed-bin histogram over base-2 logarithm of |x| — the representation the
/// paper uses for gradient distributions (Fig 1, Fig 2a).
#[derive(Clone, Debug)]
pub struct Log2Histogram {
    /// Bin i covers log2|x| ∈ [min_exp + i, min_exp + i + 1).
    pub min_exp: i32,
    pub counts: Vec<u64>,
    pub zeros: u64,
    pub total: u64,
}

impl Log2Histogram {
    pub fn new(min_exp: i32, max_exp: i32) -> Self {
        assert!(max_exp > min_exp);
        Log2Histogram {
            min_exp,
            counts: vec![0; (max_exp - min_exp) as usize],
            zeros: 0,
            total: 0,
        }
    }

    pub fn add(&mut self, x: f32) {
        self.total += 1;
        let a = x.abs();
        if a == 0.0 {
            self.zeros += 1;
            return;
        }
        let e = a.log2().floor() as i32;
        let idx = (e - self.min_exp).clamp(0, self.counts.len() as i32 - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Normalized frequencies per bin.
    pub fn freqs(&self) -> Vec<f64> {
        let t = self.total.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / t).collect()
    }

    /// Mean of |x| reconstructed from bin centers (coarse; for display).
    pub fn coarse_mean_abs(&self) -> f64 {
        let mut s = 0.0;
        for (i, &c) in self.counts.iter().enumerate() {
            let center = (self.min_exp + i as i32) as f64 + 0.5;
            s += c as f64 * center.exp2();
        }
        s / self.total.max(1) as f64
    }

    /// Render as a compact ASCII bar chart (for terminal output).
    pub fn ascii(&self, width: usize) -> String {
        let maxc = *self.counts.iter().max().unwrap_or(&1) as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let e = self.min_exp + i as i32;
            let bar = ((c as f64 / maxc.max(1.0)) * width as f64).round() as usize;
            out.push_str(&format!("  2^{e:>4} | {}{} {c}\n", "#".repeat(bar), " ".repeat(width - bar)));
        }
        out
    }
}

/// Exponential moving average (paper Eq. 3: `R_i = α·Range + (1−α)·R_{i−1}`).
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    pub alpha: f32,
    pub value: f32,
    initialized: bool,
}

impl Ema {
    pub fn new(alpha: f32) -> Self {
        Ema { alpha, value: 0.0, initialized: false }
    }

    /// Update with a new observation; first observation seeds the average.
    pub fn update(&mut self, x: f32) -> f32 {
        if !self.initialized {
            self.value = x;
            self.initialized = true;
        } else {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        }
        self.value
    }

    pub fn is_initialized(&self) -> bool {
        self.initialized
    }

    /// Overwrite the average state (checkpoint restore).
    pub fn set_state(&mut self, value: f32, initialized: bool) {
        self.value = value;
        self.initialized = initialized;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_median() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [4.0, 1.0, 3.0, 2.0]; // sorted: 1 2 3 4
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 99.0) - 3.97).abs() < 1e-12);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // Regression: `partial_cmp(..).unwrap()` panicked on the first NaN
        // latency sample, killing the serve bench/CLI mid-run.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        // NaN sorts above the finite values; the lower percentiles are the
        // same as for the finite samples alone.
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        // the top of the distribution reflects the NaN — deterministically,
        // without panicking
        assert!(percentile(&xs, 100.0).is_nan());
        // all-NaN input is still a defined (NaN) result, not a crash
        assert!(percentile(&[f64::NAN], 50.0).is_nan());
    }

    #[test]
    fn pearson_perfect_linear() {
        let m = [1.0, 2.0, 3.0, 4.0];
        let a = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson_r2(&m, &a) - 1.0).abs() < 1e-12);
        let neg = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson_r2(&m, &neg) - 1.0).abs() < 1e-12); // R² sign-blind
    }

    #[test]
    fn pearson_uncorrelated_is_small() {
        let m = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let a = [1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        assert!(pearson_r2(&m, &a) < 0.1);
    }

    #[test]
    fn pearson_constant_series_is_zero() {
        assert_eq!(pearson_r2(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn histogram_counts_and_zeros() {
        let mut h = Log2Histogram::new(-8, 4);
        h.add_all(&[0.0, 1.5, 0.25, -0.25, 1024.0, 1e-9]);
        assert_eq!(h.total, 6);
        assert_eq!(h.zeros, 1);
        // 1.5 → exp 0; ±0.25 → exp −2 (two entries); 1024 clamps to top bin;
        // 1e-9 clamps to bottom bin.
        assert_eq!(h.counts[(0 - h.min_exp) as usize], 1);
        assert_eq!(h.counts[(-2 - h.min_exp) as usize], 2);
        assert_eq!(h.counts[h.counts.len() - 1], 1);
        assert_eq!(h.counts[0], 1);
        let f: f64 = h.freqs().iter().sum();
        assert!((f - 5.0 / 6.0).abs() < 1e-12); // zeros excluded from bins
    }

    #[test]
    fn ema_tracks_constant() {
        let mut e = Ema::new(0.01);
        e.update(5.0);
        for _ in 0..100 {
            e.update(5.0);
        }
        assert!((e.value - 5.0).abs() < 1e-6);
    }

    #[test]
    fn ema_seeds_on_first() {
        let mut e = Ema::new(0.01);
        assert!(!e.is_initialized());
        e.update(42.0);
        assert_eq!(e.value, 42.0);
    }
}
