//! Result writers: CSV and a minimal JSON emitter.
//!
//! Every experiment driver writes machine-readable output under `results/`
//! in addition to its terminal table, so figures can be re-plotted without
//! re-running training.

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// CSV writer with a fixed header; rows are checked against it.
pub struct Csv {
    path: PathBuf,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new<P: AsRef<Path>>(path: P, header: &[&str]) -> Self {
        Csv {
            path: path.as_ref().to_path_buf(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: ToString>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.header.len(), "row width != header width");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    pub fn write(&self) -> io::Result<()> {
        if let Some(dir) = self.path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.header.join(","));
        for r in &self.rows {
            let _ = writeln!(s, "{}", r.join(","));
        }
        fs::write(&self.path, s)
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// Minimal JSON value for result blobs (substitute for serde_json).
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object; panics on non-objects.
    pub fn set<S: Into<String>>(&mut self, key: S, val: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                let key = key.into();
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = val;
                } else {
                    pairs.push((key, val));
                }
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_num(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => Self::escape(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn write<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.render())
    }
}

/// Results directory root (overridable for tests).
pub fn results_dir() -> PathBuf {
    std::env::var("APT_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("apt_csv_test");
        let p = dir.join("t.csv");
        let mut c = Csv::new(&p, &["a", "b"]);
        c.row(&["1", "2"]);
        c.row(&["x", "y"]);
        c.write().unwrap();
        let s = fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a,b\n1,2\nx,y\n");
    }

    #[test]
    #[should_panic]
    fn csv_width_checked() {
        let mut c = Csv::new("/tmp/x.csv", &["a", "b"]);
        c.row(&["only-one"]);
    }

    #[test]
    fn json_render() {
        let mut j = Json::obj();
        j.set("name", Json::str("fig\"1\""))
            .set("vals", Json::arr_num(&[1.0, 2.5]))
            .set("ok", Json::Bool(true))
            .set("nan", Json::Num(f64::NAN));
        let s = j.render();
        assert_eq!(
            s,
            r#"{"name":"fig\"1\"","vals":[1,2.5],"ok":true,"nan":null}"#
        );
    }

    #[test]
    fn json_set_overwrites() {
        let mut j = Json::obj();
        j.set("k", Json::num(1.0));
        j.set("k", Json::num(2.0));
        assert_eq!(j.render(), r#"{"k":2}"#);
    }
}
