//! Shared utilities: RNG, statistics, CLI parsing, output writers, timing,
//! and a minimal randomized-property-test helper.
//!
//! The offline crate set has no `rand`, `clap`, `serde`, or `proptest`;
//! these modules are deliberately small substitutes (see DESIGN.md §2).

pub mod cli;
pub mod out;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Pcg32;
pub use stats::{Ema, Log2Histogram};
pub use timer::Timer;
