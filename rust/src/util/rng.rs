//! PCG32 random number generator.
//!
//! The offline crate set has no `rand`; this is the PCG-XSH-RR 64/32
//! generator (O'Neill 2014) — tiny, fast, and statistically solid for
//! synthetic-data generation and property tests. Deterministic by seed so
//! every experiment is reproducible.

/// PCG32 generator state.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Raw generator state `(state, inc)` for checkpointing.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Pcg32::state`] snapshot; the restored
    /// generator continues the original stream bit-identically.
    pub fn from_state((state, inc): (u64, u64)) -> Self {
        Pcg32 { state, inc }
    }

    /// Next raw 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n << 2^32.
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box–Muller (caches the spare value).
    pub fn normal(&mut self) -> f32 {
        // Box–Muller without caching: simple and branch-light.
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * core::f32::consts::PI * u2).cos()
    }

    /// Normal with the given mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Pcg32::seeded(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Pcg32::seeded(17);
        for _ in 0..13 {
            a.next_u32();
        }
        let mut b = Pcg32::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }
}
