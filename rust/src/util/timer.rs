//! Wall-clock timing helpers used by the bench harness and experiments.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds.
    pub fn nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }

    pub fn restart(&mut self) {
        self.start = Instant::now();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
