//! Minimal randomized-property-test helper (offline substitute for the
//! `proptest` crate — see DESIGN.md §2).
//!
//! A property is a closure over a [`Gen`]; [`check`] runs it across many
//! seeded cases and reports the first failing seed so failures reproduce
//! exactly (`APT_PROPTEST_SEED=<seed>` reruns a single case).

use crate::util::rng::Pcg32;

/// Value generator handed to properties.
pub struct Gen {
    pub rng: Pcg32,
    pub size: usize,
}

impl Gen {
    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as usize) as i64
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// f32 in [lo, hi).
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    /// Log-uniform positive f32 in [lo, hi) — spans decades evenly.
    pub fn f32_log(&mut self, lo: f32, hi: f32) -> f32 {
        debug_assert!(lo > 0.0 && hi > lo);
        (self.rng.range(lo.ln(), hi.ln())).exp()
    }

    /// Gaussian vector with the given std.
    pub fn normal_vec(&mut self, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal() * std).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` over `cases` generated cases. Panics with the failing seed on
/// the first property violation (the closure should panic/assert on failure).
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    if let Ok(seed) = std::env::var("APT_PROPTEST_SEED") {
        let seed: u64 = seed.parse().expect("APT_PROPTEST_SEED must be u64");
        let mut g = Gen { rng: Pcg32::seeded(seed), size: 64 };
        prop(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x5eed_0000 + case as u64;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Pcg32::seeded(seed), size: 64 };
            prop(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {case} (APT_PROPTEST_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("abs-nonneg", 50, |g| {
            let x = g.f32(-10.0, 10.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_g| panic!("boom"));
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>().unwrap());
        assert!(msg.contains("APT_PROPTEST_SEED"), "{msg}");
    }

    #[test]
    fn gen_ranges_hold() {
        check("gen-ranges", 20, |g| {
            let i = g.int(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f32_log(1e-3, 1e3);
            assert!((1e-3..1e3).contains(&f));
            let n = g.size;
            let v = g.normal_vec(n, 2.0);
            assert_eq!(v.len(), 64);
        });
    }
}
