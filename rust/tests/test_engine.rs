//! Integration: the parallel kernel engine against the serial `fixedpoint`
//! backends — the determinism contract of DESIGN.md §Kernel-Engine:
//! parallel i8/i16 GEMM bit-identical to serial at every thread count
//! (f32 also bit-identical with row-panel sharding, so we assert equality
//! there too), across edge shapes and a randomized property sweep.

use apt::fixedpoint::quantize::max_abs;
use apt::fixedpoint::{gemm, gemm_simd, Scheme};
use apt::kernels::Engine;
use apt::util::proptest::check;
use apt::util::Pcg32;

const THREAD_COUNTS: [usize; 4] = [1, 2, 3, 4];

/// Shapes chosen to hit every dispatch corner: below/above the parallel
/// threshold, m/k/n smaller than one MC/KC panel, k below the VNNI (64) and
/// vpmaddwd (32) minimums, SIMD tail remainders, and single rows/columns.
const EDGE_SHAPES: [(usize, usize, usize); 8] = [
    (1, 1, 1),
    (1, 65, 1),
    (3, 31, 5),
    (2, 64, 2),
    (7, 100, 9),
    (65, 130, 33),
    (128, 257, 96),
    (160, 128, 160),
];

fn rand_f32(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

fn rand_i8(rng: &mut Pcg32, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
}

fn rand_i16(rng: &mut Pcg32, n: usize) -> Vec<i16> {
    (0..n).map(|_| (rng.below(65535) as i32 - 32767) as i16).collect()
}

#[test]
fn i8_gemm_bit_identical_across_thread_counts() {
    let mut rng = Pcg32::seeded(1);
    for &(m, k, n) in &EDGE_SHAPES {
        let a = rand_i8(&mut rng, m * k);
        let b = rand_i8(&mut rng, k * n);
        let mut want = vec![0i32; m * n];
        gemm::gemm_i8(m, k, n, &a, &b, &mut want);
        for &t in &THREAD_COUNTS {
            let eng = Engine::new(t);
            let mut got = vec![0i32; m * n];
            eng.gemm_i8(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "i8 {m}x{k}x{n} threads={t}");
        }
    }
}

#[test]
fn i16_gemm_bit_identical_across_thread_counts() {
    let mut rng = Pcg32::seeded(2);
    for &(m, k, n) in &EDGE_SHAPES {
        let a = rand_i16(&mut rng, m * k);
        let b: Vec<i16> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i16).collect();
        let mut want = vec![0i32; m * n];
        gemm::gemm_i16(m, k, n, &a, &b, &mut want);
        for &t in &THREAD_COUNTS {
            let eng = Engine::new(t);
            let mut got = vec![0i32; m * n];
            eng.gemm_i16(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "i16 {m}x{k}x{n} threads={t}");
        }
    }
}

#[test]
fn f32_gemm_bit_identical_across_thread_counts() {
    // Row-panel sharding leaves each output row's accumulation order
    // unchanged, so even f32 is exactly reproducible.
    let mut rng = Pcg32::seeded(3);
    for &(m, k, n) in &EDGE_SHAPES {
        let a = rand_f32(&mut rng, m * k, 1.0);
        let b = rand_f32(&mut rng, k * n, 0.3);
        let mut want = vec![0.0f32; m * n];
        gemm::gemm_f32(m, k, n, &a, &b, &mut want);
        for &t in &THREAD_COUNTS {
            let eng = Engine::new(t);
            let mut got = vec![0.0f32; m * n];
            eng.gemm_f32(m, k, n, &a, &b, &mut got);
            assert_eq!(got, want, "f32 {m}x{k}x{n} threads={t}");
        }
    }
}

#[test]
fn prepacked_paths_match_serial() {
    let mut rng = Pcg32::seeded(4);
    let (m, k, n) = (130usize, 96, 48);
    let a8 = rand_i8(&mut rng, m * k);
    let b8 = rand_i8(&mut rng, k * n);
    let mut bt8 = vec![0i8; k * n];
    let mut colsum = vec![0i32; n];
    gemm_simd::pack_bt_i8(k, n, &b8, &mut bt8, &mut colsum);
    let mut want8 = vec![0i32; m * n];
    gemm_simd::gemm_i8_prepacked(m, k, n, &a8, &bt8, &colsum, &mut want8);

    let a16 = rand_i16(&mut rng, m * k);
    let b16: Vec<i16> = (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i16).collect();
    let mut bt16 = vec![0i16; k * n];
    gemm_simd::pack_bt_i16(k, n, &b16, &mut bt16);
    let mut want16 = vec![0i32; m * n];
    gemm_simd::gemm_i16_prepacked(m, k, n, &a16, &bt16, &mut want16);

    for &t in &THREAD_COUNTS {
        let eng = Engine::new(t);
        let mut got8 = vec![0i32; m * n];
        eng.gemm_i8_prepacked(m, k, n, &a8, &bt8, &colsum, &mut got8);
        assert_eq!(got8, want8, "prepacked i8 threads={t}");
        let mut got16 = vec![0i32; m * n];
        eng.gemm_i16_prepacked(m, k, n, &a16, &bt16, &mut got16);
        assert_eq!(got16, want16, "prepacked i16 threads={t}");
    }
}

#[test]
fn prop_engine_gemms_match_portable_oracle() {
    // Randomized cross-check straight against the *portable* kernels —
    // independently covers both the SIMD selection (serial dispatch) and
    // the sharding (parallel dispatch).
    let eng2 = Engine::new(2);
    let eng4 = Engine::new(4);
    check("engine-vs-portable", 20, |g| {
        let m = g.usize(1, 80);
        let k = g.usize(1, 140);
        let n = g.usize(1, 70);
        let mut rng = Pcg32::seeded(g.usize(0, 1 << 30) as u64);
        let a8 = rand_i8(&mut rng, m * k);
        let b8 = rand_i8(&mut rng, k * n);
        let mut want = vec![0i32; m * n];
        gemm::gemm_i8_portable(m, k, n, &a8, &b8, &mut want);
        for eng in [&eng2, &eng4] {
            let mut got = vec![0i32; m * n];
            eng.gemm_i8(m, k, n, &a8, &b8, &mut got);
            assert_eq!(got, want, "i8 {m}x{k}x{n} threads={}", eng.threads());
        }

        let a16 = rand_i16(&mut rng, m * k);
        let b16: Vec<i16> =
            (0..k * n).map(|_| (rng.below(255) as i32 - 127) as i16).collect();
        let mut want16 = vec![0i32; m * n];
        gemm::gemm_i16_portable(m, k, n, &a16, &b16, &mut want16);
        for eng in [&eng2, &eng4] {
            let mut got = vec![0i32; m * n];
            eng.gemm_i16(m, k, n, &a16, &b16, &mut got);
            assert_eq!(got, want16, "i16 {m}x{k}x{n} threads={}", eng.threads());
        }
    });
}

#[test]
fn conv_engine_matches_serial_conv() {
    use apt::fixedpoint::conv::{conv2d_f32, Conv2dGeom};
    let g = Conv2dGeom { in_c: 3, out_c: 8, kh: 3, kw: 3, stride: 1, pad: 1 };
    let (h, w) = (14usize, 14usize);
    let mut rng = Pcg32::seeded(5);
    let img = rand_f32(&mut rng, g.in_c * h * w, 1.0);
    let weight = rand_f32(&mut rng, g.out_c * g.in_c * g.kh * g.kw, 0.2);
    let (rows, cols) = g.im2col_dims(h, w);
    let mut want = vec![0.0f32; g.out_c * cols];
    let mut scratch = vec![0.0f32; rows * cols];
    conv2d_f32(g, h, w, &img, &weight, &mut want, &mut scratch);
    for &t in &THREAD_COUNTS {
        let eng = Engine::new(t);
        let mut got = vec![0.0f32; g.out_c * cols];
        let mut scratch = vec![0.0f32; rows * cols];
        eng.conv2d_f32(g, h, w, &img, &weight, &mut got, &mut scratch);
        assert_eq!(got, want, "conv threads={t}");
    }

    // quantized conv: engine vs serial fixedpoint path
    let s_img = Scheme::for_range(max_abs(&img), 8);
    let s_w = Scheme::for_range(max_abs(&weight), 8);
    let mut want_q = vec![0.0f32; g.out_c * cols];
    apt::fixedpoint::conv::conv2d_i8(g, h, w, &img, s_img, &weight, s_w, &mut want_q);
    for &t in &THREAD_COUNTS {
        let eng = Engine::new(t);
        let mut got_q = vec![0.0f32; g.out_c * cols];
        eng.conv2d_i8(g, h, w, &img, s_img, &weight, s_w, &mut got_q);
        assert_eq!(got_q, want_q, "conv2d_i8 threads={t}");
    }
}

#[test]
fn quantize_and_rescale_match_serial() {
    let mut rng = Pcg32::seeded(6);
    // cross the elementwise parallel threshold (1<<16)
    let xs = rand_f32(&mut rng, (1 << 16) + 777, 2.0);
    let sch8 = Scheme::for_range(max_abs(&xs), 8);
    let sch16 = Scheme::for_range(max_abs(&xs), 16);
    let mut want8 = vec![0i8; xs.len()];
    apt::fixedpoint::quantize::codes_i8(&xs, &mut want8, sch8);
    let mut want16 = vec![0i16; xs.len()];
    apt::fixedpoint::quantize::codes_i16(&xs, &mut want16, sch16);
    let acc: Vec<i32> = (0..xs.len()).map(|i| i as i32 - 4000).collect();
    let mut want_r = vec![0.0f32; xs.len()];
    gemm::rescale_i32(&acc, 0.125, &mut want_r);

    for &t in &THREAD_COUNTS {
        let eng = Engine::new(t);
        let mut got8 = vec![0i8; xs.len()];
        eng.codes_i8(&xs, &mut got8, sch8);
        assert_eq!(got8, want8, "codes_i8 threads={t}");
        let mut got16 = vec![0i16; xs.len()];
        eng.codes_i16(&xs, &mut got16, sch16);
        assert_eq!(got16, want16, "codes_i16 threads={t}");
        let mut got_r = vec![0.0f32; xs.len()];
        eng.rescale_i32(&acc, 0.125, &mut got_r);
        assert_eq!(got_r, want_r, "rescale threads={t}");
    }
}

#[test]
fn nn_training_deterministic_across_engine_widths() {
    // End-to-end: one train step of the mini classifier must produce the
    // same loss whether the global engine happens to be serial or wide —
    // exercised here with explicit engines through the tensor API.
    let eng1 = Engine::serial();
    let eng4 = Engine::new(4);
    let mut rng = Pcg32::seeded(9);
    let a = apt::tensor::Tensor::from_vec(&[48, 96], rand_f32(&mut rng, 48 * 96, 1.0));
    let b = apt::tensor::Tensor::from_vec(&[96, 144], rand_f32(&mut rng, 96 * 144, 1.0));
    let y1 = a.matmul_with(&b, &eng1);
    let y4 = a.matmul_with(&b, &eng4);
    assert_eq!(y1.data, y4.data);
}
