//! Independent re-implementations of the data-parallel reduction and step
//! sequence (DESIGN.md §Data-Parallel), shared by `test_parallel.rs` and
//! `test_compress_props.rs`. These are *oracles*: they rebuild the
//! documented semantics from public primitives only, so a bit-exact match
//! pins the production path against its spec rather than against itself.

use apt::data::SynthImages;
use apt::nn::loss::softmax_xent;
use apt::nn::{models, QuantMode, TrainCtx};
use apt::train::{Optimizer, Sgd};
use apt::util::Pcg32;

/// The documented reduction ladder: recursive split at the largest power
/// of two strictly below `n`, which is provably the same association as
/// the stride-doubling loop in `train::parallel::tree_reduce_f32`.
pub fn oracle_tree(parts: &[Vec<f32>]) -> Vec<f32> {
    let n = parts.len();
    if n == 1 {
        return parts[0].clone();
    }
    let mut p = 1usize;
    while p * 2 < n {
        p *= 2;
    }
    let left = oracle_tree(&parts[..p]);
    let right = oracle_tree(&parts[p..]);
    left.iter().zip(&right).map(|(a, b)| a + b).collect()
}

/// The two-level hierarchical schedule: tree within consecutive
/// power-of-two `node`-chunks, then tree over the chunk sums. By the
/// `hier_reduce_f32` lemma this equals [`oracle_tree`] bit-for-bit — the
/// property battery checks both against the production ladder.
pub fn oracle_hier(parts: &[Vec<f32>], node: usize) -> Vec<f32> {
    assert!(node >= 1 && node.is_power_of_two(), "oracle node size must be a power of two");
    let sums: Vec<Vec<f32>> = parts.chunks(node).map(oracle_tree).collect();
    oracle_tree(&sums)
}

/// The data-parallel step sequence, rebuilt from public primitives only:
/// N identically seeded nets, one shared batch stream, row-sharding,
/// per-replica backward, oracle tree reduction + mean, per-replica SGD.
/// Returns the (group loss curve, root replica's final parameters).
pub fn oracle_parallel(
    model: &str,
    mode: QuantMode,
    replicas: usize,
    iters: u64,
    lr: f32,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let batch = 16usize;
    assert_eq!(batch % replicas, 0, "oracle batch must split evenly");
    let shard = batch / replicas;
    let mut nets: Vec<_> = (0..replicas)
        .map(|_| {
            let mut rng = Pcg32::seeded(0);
            models::by_name(model, mode, &mut rng).expect("model")
        })
        .collect();
    let mut ctxs: Vec<TrainCtx> = (0..replicas).map(|_| TrainCtx::new()).collect();
    let mut opts: Vec<Sgd> = (0..replicas).map(|_| Sgd::new(lr, 0.9)).collect();
    let mut data = SynthImages::new(
        1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let mut losses = Vec::new();
    for it in 0..iters {
        let (x, y) = data.batch(batch);
        let d = x.dim(1);
        let mut shard_losses = Vec::new();
        let mut grads: Vec<Vec<Vec<f32>>> = Vec::new();
        for r in 0..replicas {
            ctxs[r].iter = it;
            let xs = apt::tensor::Tensor::from_vec(
                &[shard, d],
                x.data[r * shard * d..(r + 1) * shard * d].to_vec(),
            );
            let ys = &y[r * shard..(r + 1) * shard];
            let logits = nets[r].forward(&xs, &mut ctxs[r]);
            let (l, g) = softmax_xent(&logits, ys);
            nets[r].backward(&g, &mut ctxs[r]);
            shard_losses.push(l);
            let mut gs = Vec::new();
            nets[r].visit_params(&mut |_, gr| gs.push(gr.data.clone()));
            grads.push(gs);
        }
        let tensors = grads[0].len();
        let mut avg: Vec<Vec<f32>> = Vec::with_capacity(tensors);
        for t in 0..tensors {
            let parts: Vec<Vec<f32>> = grads.iter().map(|g| g[t].clone()).collect();
            let mut sum = oracle_tree(&parts);
            let inv = 1.0 / replicas as f32;
            for v in &mut sum {
                *v *= inv;
            }
            avg.push(sum);
        }
        for r in 0..replicas {
            let mut i = 0usize;
            nets[r].visit_params(&mut |_, gr| {
                gr.data.copy_from_slice(&avg[i]);
                i += 1;
            });
            opts[r].step(&mut nets[r]);
            nets[r].zero_grads();
        }
        losses.push(
            (shard_losses.iter().map(|&l| l as f64).sum::<f64>() / replicas as f64) as f32,
        );
    }
    let mut params = Vec::new();
    nets[0].visit_params(&mut |p, _| params.push(p.data.clone()));
    (losses, params)
}
