//! Shared helpers for the integration-test crates. Each test crate pulls
//! this in with `mod common;` — cargo compiles a copy per crate, so not
//! every crate uses every helper.
#![allow(dead_code)]

pub mod oracle;
