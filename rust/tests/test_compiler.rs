//! `compiler::` contract tests (DESIGN.md §Inference-Compiler) — the gate
//! for the fused serving path:
//!
//! 1. **Fused ≡ unfused** — for every zoo model family (plain MLP, conv
//!    stacks, residual add-back, Inception concat, depthwise) and mode,
//!    the fused plan's logits are *bit-identical* to the unfused reference
//!    interpreter. Fusion is a scheduling/layout decision, never a
//!    numerics decision.
//! 2. **Freeze-time validation** — malformed value-stack programs fail at
//!    compile time with the op index named, never as an exec-time panic
//!    inside a serve worker.
//! 3. **Plan cache** — `--tune` tile decisions round-trip through the
//!    checkpoint's `tune` section: a second load answers every shape from
//!    the cache, serves bit-identically, and the file still restores into
//!    a training session (the trailing section is serving-only).

use apt::compiler::CompileOptions;
use apt::data::SynthImages;
use apt::fixedpoint::{Format, Scheme};
use apt::kernels::Engine;
use apt::nn::{models, QuantMode};
use apt::serve::{FrozenModel, InferOp};
use apt::tensor::Tensor;
use apt::train::checkpoint::Checkpoint;
use apt::train::{HostBackend, Session, SessionBuilder};

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_compiler_ckpt_{tag}_{}.txt", std::process::id()))
}

/// Builder-default eval batch: the stream `Session::eval` reads.
fn eval_batch(n: usize) -> Tensor {
    let data = SynthImages::new(
        1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    data.eval_set(999, n).0
}

fn assert_bits_equal(want: &Tensor, got: &Tensor, tag: &str) {
    assert_eq!(want.shape, got.shape, "{tag}: shape");
    for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: logit {i} diverged ({a} vs {b})");
    }
}

fn train_net(model: &str, mode: QuantMode, iters: u64) -> Session<'static, HostBackend> {
    let mut s = SessionBuilder::classifier(model).mode(mode).lr(0.01).build();
    s.run(iters).unwrap();
    s
}

#[test]
fn fused_bit_identical_to_unfused_across_zoo() {
    // Batch > 1 so fused batching/tiling decisions are exercised; models
    // cover every fusion pattern: plain GEMM chain (mlp), conv + maxpool
    // (alexnet), BN + residual AddPopRelu (resnet), ConcatPop branch merge
    // (inception), depthwise + GAP (mobilenet).
    for (model, mode, iters) in [
        ("mlp", QuantMode::Float32, 20),
        ("mlp", QuantMode::Static(8), 20),
        ("mlp", QuantMode::Static(16), 20),
        ("alexnet", QuantMode::Static(8), 10),
        ("resnet", QuantMode::Static(8), 10),
        ("inception", QuantMode::Static(8), 10),
        ("mobilenet", QuantMode::Static(8), 10),
    ] {
        let tag = format!("{model}-{}", mode.label());
        let s = train_net(model, mode, iters);
        let fused = FrozenModel::freeze(tag.clone(), s.net()).unwrap();
        assert!(fused.fused(), "{tag}: default freeze must build a plan");
        let ex = eval_batch(32);
        let eng = Engine::serial();
        let got = fused.forward(&ex, &eng);
        let want = fused.forward_unfused(&ex, &eng);
        assert_bits_equal(&want, &got, &tag);

        // A model frozen with fusion off (the --no-fuse path) runs the
        // interpreter as its *primary* path and must land on the same bits.
        let opts = CompileOptions { fuse: false, ..CompileOptions::default() };
        let unfused = FrozenModel::freeze_with(tag.clone(), s.net(), &opts).unwrap();
        assert!(!unfused.fused());
        assert_bits_equal(&want, &unfused.forward(&ex, &eng), &format!("{tag}-nofuse"));

        // Fusion must actually fuse something on the quantized models:
        // fewer steps than ops and at least one integer edge.
        let rep = fused.compile_report();
        assert_eq!(rep.ops, unfused.compile_report().steps, "{tag}: op count");
        if !matches!(mode, QuantMode::Float32) {
            assert!(rep.steps < rep.ops, "{tag}: {} steps for {} ops", rep.steps, rep.ops);
            assert!(rep.code_edges > 0, "{tag}: no code edges");
        }
    }
}

#[test]
fn fused_multithreaded_engine_matches_serial() {
    // Thread count is a scheduling decision too: the fused plan on a
    // 4-thread engine must reproduce the serial bits exactly.
    let s = train_net("resnet", QuantMode::Static(8), 8);
    let frozen = FrozenModel::freeze("resnet-int8", s.net()).unwrap();
    let ex = eval_batch(16);
    let serial = frozen.forward(&ex, &Engine::serial());
    let parallel = frozen.forward(&ex, &Engine::new(4));
    assert_bits_equal(&serial, &parallel, "resnet-int8-threads");
}

// ---- freeze-time validation (satellite: never an exec-time panic) ----

fn lin(name: &str, din: usize, dout: usize) -> InferOp {
    let w: Vec<f32> = (0..din * dout).map(|i| ((i * 7 + 3) % 13) as f32 * 0.01 - 0.06).collect();
    InferOp::Linear {
        name: name.to_string(),
        w: Tensor::from_vec(&[din, dout], w),
        b: vec![0.1; dout],
        sw: Some(Format::FixedPoint(Scheme { bits: 8, s: -6 })),
        sx: Some(Format::FixedPoint(Scheme { bits: 8, s: -5 })),
    }
}

#[test]
fn freeze_rejects_stack_underflow_naming_the_op() {
    let opts = CompileOptions::default();
    // AddPopRelu with nothing pushed: underflow at op 1.
    let err = FrozenModel::from_infer_ops("bad", vec![lin("fc0", 4, 4), InferOp::AddPopRelu], &opts)
        .unwrap_err()
        .to_string();
    assert!(err.contains("op 1"), "must name the op index: {err}");
    assert!(err.contains("underflows"), "unexpected error: {err}");
    assert!(err.contains("bad"), "must name the model: {err}");

    // Swap and ConcatPop underflow the same way.
    for (i, op) in [InferOp::Swap, InferOp::ConcatPop { c_pop: 1, c_cur: 1, hw: 4 }]
        .into_iter()
        .enumerate()
    {
        let err = FrozenModel::from_infer_ops("bad2", vec![lin("fc0", 4, 4), op], &opts)
            .unwrap_err()
            .to_string();
        assert!(err.contains("op 1") && err.contains("underflows"), "case {i}: {err}");
    }
}

#[test]
fn freeze_rejects_leftover_stack_entries_and_headless_programs() {
    let opts = CompileOptions::default();
    // Push with no matching pop: a tensor is left on the stack at the end.
    let err = FrozenModel::from_infer_ops(
        "leak",
        vec![lin("fc0", 4, 4), InferOp::Push, lin("fc1", 4, 4)],
        &opts,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("unconsumed"), "unexpected error: {err}");

    // No leading layer: the input width cannot be inferred.
    let err = FrozenModel::from_infer_ops("headless", vec![InferOp::Relu], &opts)
        .unwrap_err()
        .to_string();
    assert!(err.contains("input width"), "unexpected error: {err}");
}

#[test]
fn valid_hand_built_program_compiles_and_runs_both_paths() {
    // A residual block in miniature: fc0 → push → fc1 → add+relu → fc2.
    // Bit-identity between the fused plan (which collapses the AddPopRelu
    // into fc1's epilogue) and the interpreter, on a hand-built program.
    let ops = vec![
        lin("fc0", 6, 4),
        InferOp::Push,
        lin("fc1", 4, 4),
        InferOp::AddPopRelu,
        lin("fc2", 4, 3),
    ];
    let m = FrozenModel::from_infer_ops("resmini", ops, &CompileOptions::default()).unwrap();
    assert_eq!(m.input_len(), 6);
    assert_eq!(m.precision(), "int8");
    let x = Tensor::from_vec(&[2, 6], (0..12).map(|i| i as f32 * 0.11 - 0.5).collect());
    let eng = Engine::serial();
    assert_bits_equal(&m.forward_unfused(&x, &eng), &m.forward(&x, &eng), "resmini");
}

// ---- plan cache: tune → checkpoint → reload ----

#[test]
fn tune_cache_roundtrips_through_checkpoint_and_keeps_bits() {
    let path = ckpt_path("tune");
    let mut s = SessionBuilder::classifier("alexnet").mode(QuantMode::Static(8)).lr(0.01).build();
    s.run(8).unwrap();
    s.save_checkpoint(&path).unwrap();

    // First load searches (no cache in a fresh training checkpoint).
    let tuned = CompileOptions { tune: true, ..CompileOptions::default() };
    let m1 = FrozenModel::from_checkpoint_with(&path, "alexnet", QuantMode::Static(8), &tuned)
        .unwrap();
    let rep1 = m1.compile_report();
    assert!(rep1.tiles_tuned > 0, "tune load must search");
    assert_eq!(rep1.tiles_cached, 0);
    let entries = m1.tuned_tiles().to_vec();
    assert_eq!(entries.len(), rep1.tiles_tuned);

    // Persist and reload: every shape answers from the cache, bits agree.
    Checkpoint::write_tune_cache(&path, &entries).unwrap();
    assert_eq!(Checkpoint::read(&path).unwrap().tune_cache(), entries.as_slice());
    let m2 = FrozenModel::from_checkpoint_with(&path, "alexnet", QuantMode::Static(8), &tuned)
        .unwrap();
    assert_eq!(m2.compile_report().tiles_tuned, 0);
    assert_eq!(m2.compile_report().tiles_cached, entries.len());
    assert_eq!(m2.tuned_tiles(), entries.as_slice());
    let ex = eval_batch(16);
    let eng = Engine::serial();
    assert_bits_equal(&m1.forward(&ex, &eng), &m2.forward(&ex, &eng), "tiles-change-no-bits");
    // Tiles are speed-only: the untuned default plan lands on the same bits.
    let m3 = FrozenModel::from_checkpoint(&path, "alexnet", QuantMode::Static(8)).unwrap();
    assert_bits_equal(&m1.forward(&ex, &eng), &m3.forward(&ex, &eng), "tuned-vs-default");

    // write_tune_cache is idempotent (replaces, not appends).
    Checkpoint::write_tune_cache(&path, &entries).unwrap();
    assert_eq!(Checkpoint::read(&path).unwrap().tune_cache(), entries.as_slice());

    // The training payload is untouched: the file still restores into a
    // session (the tune section is serving-only tail data).
    let mut s2 = SessionBuilder::classifier("alexnet").mode(QuantMode::Static(8)).lr(0.01).build();
    s2.load_checkpoint(&path).unwrap();
    let _ = std::fs::remove_file(&path);
}

// ---- compile report + per-step timings ----

#[test]
fn compile_report_and_timing_report_expose_the_plan() {
    let s = train_net("mlp", QuantMode::Static(8), 10);
    let frozen = FrozenModel::freeze("mlp-int8", s.net()).unwrap();
    let rep = format!("{}", frozen.compile_report());
    assert!(rep.contains("mlp-int8"), "report: {rep}");
    assert!(rep.contains("ops ->"), "report: {rep}");
    assert_eq!(frozen.compile_report().lines.len(), frozen.compile_report().steps);

    assert!(frozen.timing_report().is_none(), "no timings before the first forward");
    let ex = eval_batch(8);
    frozen.forward(&ex, &Engine::serial());
    let t = frozen.timing_report().expect("timings after a forward");
    assert!(t.contains("mlp-int8"), "timing: {t}");
    assert!(t.contains("us/call"), "timing: {t}");
    assert_eq!(t.lines().count(), 1 + frozen.compile_report().steps, "one line per step");
}
