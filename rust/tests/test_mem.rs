//! Integration tests for the quantized activation-memory subsystem
//! (`mem::`, DESIGN.md §Activation-Memory):
//!
//! - `StashPolicy::F32` sessions are bit-identical to the default build
//!   (the seed contract), across f32/int8/adaptive compute;
//! - recompute checkpointing is bit-identical to stashing under F32
//!   storage, for the host loop and for alexnet's conv patches;
//! - int8/int16 storage respects the half-resolution decode bound and cuts
//!   alexnet's peak stashed bytes ≥3× (ISSUE 5 acceptance);
//! - adaptive-stash sessions converge on the tier-1 mlp/alexnet configs;
//! - checkpoint v3 round-trips the stash controllers bit-identically and
//!   rejects policy mismatches without mutating the session;
//! - committed v1/v2 fixture files keep loading under the v3 reader.

use apt::apt::AptConfig;
use apt::data::SynthImages;
use apt::mem::StashPolicy;
use apt::nn::linear::Linear;
use apt::nn::{QuantMode, Sequential};
use apt::train::checkpoint::Checkpoint;
use apt::train::{CommPrecision, SessionBuilder};

fn adaptive_compute(init: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = init;
    QuantMode::Adaptive(cfg)
}

fn adaptive_stash(init: u64) -> StashPolicy {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = init;
    cfg.pin_forward_bits = false;
    StashPolicy::Adaptive(cfg)
}

/// Train `model` under the given compute mode / stash policy and return
/// (losses, final params, eval accuracy).
fn run_with(
    model: &str,
    mode: QuantMode,
    policy: StashPolicy,
    recompute: bool,
    iters: u64,
) -> (Vec<f32>, Vec<Vec<f32>>, f64) {
    let mut s = SessionBuilder::classifier(model)
        .mode(mode)
        .stash_policy(policy)
        .recompute(recompute)
        .build();
    s.run(iters).unwrap();
    let mut params = Vec::new();
    s.net_mut().visit_params(&mut |p, _| params.push(p.data.clone()));
    let rec = s.record().unwrap();
    (rec.losses, params, rec.eval_acc)
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_mem_{tag}_{}.txt", std::process::id()))
}

fn fixture(name: &str) -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

// ---------------------------------------------------------------- identity

#[test]
fn f32_policy_is_bit_identical_to_default_build() {
    for (model, mode, iters) in [
        ("mlp", QuantMode::Float32, 15),
        ("mlp", QuantMode::Static(8), 15),
        ("mlp", adaptive_compute(2), 15),
        ("alexnet", adaptive_compute(2), 8),
    ] {
        // default build (no stash calls at all — the seed configuration)
        let mut s = SessionBuilder::classifier(model).mode(mode).build();
        s.run(iters).unwrap();
        let mut params_default = Vec::new();
        s.net_mut().visit_params(&mut |p, _| params_default.push(p.data.clone()));
        let rec_default = s.record().unwrap();

        let (losses, params, acc) =
            run_with(model, mode, StashPolicy::F32, false, iters);
        assert_eq!(rec_default.losses, losses, "{model} losses diverged");
        assert_eq!(params_default, params, "{model} params diverged");
        assert_eq!(rec_default.eval_acc, acc, "{model} eval diverged");
    }
}

#[test]
fn recompute_is_bit_identical_under_f32_storage() {
    // Schemes are frozen between forward and backward of one step and
    // parameters only move after backward, so re-deriving X̂/Ŵ/patches is
    // exact — for every compute mode, linear (mlp) and conv (alexnet).
    for (model, mode, iters) in [
        ("mlp", QuantMode::Float32, 15),
        ("mlp", QuantMode::Static(8), 15),
        ("mlp", adaptive_compute(2), 15),
        ("alexnet", QuantMode::Float32, 8),
        ("alexnet", adaptive_compute(2), 8),
    ] {
        let (l_stash, p_stash, a_stash) =
            run_with(model, mode, StashPolicy::F32, false, iters);
        let (l_rc, p_rc, a_rc) = run_with(model, mode, StashPolicy::F32, true, iters);
        assert_eq!(l_stash, l_rc, "{model}: recompute losses diverged");
        assert_eq!(p_stash, p_rc, "{model}: recompute params diverged");
        assert_eq!(a_stash, a_rc, "{model}: recompute eval diverged");
    }
}

#[test]
fn parallel_n1_parity_holds_with_quantized_stash() {
    // The data-parallel builder at N=1 must stay bit-identical to the host
    // loop under every stash policy, not just the default.
    for policy in [StashPolicy::F32, StashPolicy::Int8, adaptive_stash(2)] {
        let mut host = SessionBuilder::classifier("mlp")
            .mode(QuantMode::Static(8))
            .stash_policy(policy)
            .build();
        host.run(12).unwrap();
        let host_rec = host.record().unwrap();

        let mut par = SessionBuilder::classifier("mlp")
            .mode(QuantMode::Static(8))
            .stash_policy(policy)
            .build_parallel(1, CommPrecision::F32)
            .unwrap();
        par.run(12).unwrap();
        let par_rec = par.record().unwrap();
        assert_eq!(host_rec.losses, par_rec.losses, "{}", policy.label());
        assert_eq!(host_rec.eval_acc, par_rec.eval_acc, "{}", policy.label());
    }
}

#[test]
fn parallel_replicas_stay_in_sync_with_quantized_stash() {
    let mut s = SessionBuilder::classifier("mlp")
        .mode(QuantMode::Static(8))
        .stash_policy(StashPolicy::Int8)
        .recompute(true)
        .build_parallel(2, CommPrecision::Static(8))
        .unwrap();
    s.run(8).unwrap();
    assert!(s.replicas_in_sync(), "int8 stash broke the sync invariant");
    assert!(s.mem().peak_bytes() > 0, "root replica stash never measured");
}

// ------------------------------------------------------------ compression

#[test]
fn int8_storage_cuts_alexnet_peak_at_least_3x() {
    // ISSUE 5 acceptance: ≥3× lower peak stashed bytes for int8 vs f32
    // storage on alexnet (the conv patch matrices dominate and shrink 4×;
    // bitset masks / u32 argmax are policy-invariant).
    let peak = |policy, recompute| {
        let mut s = SessionBuilder::classifier("alexnet")
            .stash_policy(policy)
            .recompute(recompute)
            .build();
        s.run(3).unwrap();
        s.mem().peak_bytes()
    };
    let f = peak(StashPolicy::F32, false);
    let q = peak(StashPolicy::Int8, false);
    assert!(f > 0 && q > 0);
    let ratio = f as f64 / q as f64;
    assert!(ratio >= 3.0, "int8 peak {q} vs f32 peak {f}: only {ratio:.2}×");

    // recompute drops the patch matrices — an additional large cut
    let rc = peak(StashPolicy::F32, true);
    assert!(
        (rc as f64) < 0.5 * f as f64,
        "recompute peak {rc} not well below stash peak {f}"
    );
}

#[test]
fn int16_storage_halves_int8_error() {
    // End-to-end decode bound: a quantized-stash mlp run must track the
    // f32-storage run within a loss tolerance that shrinks with width.
    let (l_f32, _, _) = run_with("mlp", QuantMode::Float32, StashPolicy::F32, false, 20);
    let (l_i8, _, _) = run_with("mlp", QuantMode::Float32, StashPolicy::Int8, false, 20);
    let (l_i16, _, _) = run_with("mlp", QuantMode::Float32, StashPolicy::Int16, false, 20);
    let dev = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs() as f64)
            .sum::<f64>()
            / a.len() as f64
    };
    let d8 = dev(&l_f32, &l_i8);
    let d16 = dev(&l_f32, &l_i16);
    assert!(d16 <= d8 + 1e-9, "int16 deviation {d16} above int8 {d8}");
    assert!(d16 < 0.05, "int16 storage deviates too far from f32: {d16}");
    // and int8 storage still converges
    assert!(
        l_i8.last().unwrap() < &(l_i8[0] * 0.8),
        "int8-storage mlp failed to converge: {:?} → {:?}",
        l_i8[0],
        l_i8.last()
    );
}

// ------------------------------------------------------------- convergence

#[test]
fn adaptive_stash_converges_on_mlp() {
    let (losses, _, acc) = run_with(
        "mlp",
        adaptive_compute(6),
        adaptive_stash(6),
        false,
        60,
    );
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.8),
        "first={} last={}",
        losses[0],
        losses.last().unwrap()
    );
    assert!(acc > 0.25, "adaptive-stash mlp acc {acc}");
}

#[test]
fn adaptive_stash_converges_on_alexnet_with_recompute() {
    let (losses, _, acc) = run_with(
        "alexnet",
        adaptive_compute(4),
        adaptive_stash(4),
        true,
        40,
    );
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.9),
        "first={} last={}",
        losses[0],
        losses.last().unwrap()
    );
    assert!(acc > 0.2, "adaptive-stash alexnet acc {acc}");
}

#[test]
fn adaptive_stash_fills_ledger_and_reports_bits() {
    let mut s = SessionBuilder::classifier("mlp")
        .stash_policy(adaptive_stash(2))
        .build();
    s.run(12).unwrap();
    let bits = s.stash().stash_bits();
    assert!(!bits.is_empty(), "no stash controllers created");
    assert!(bits.iter().all(|(k, _)| k.starts_with("stash:")));
    let rec = s.record().unwrap();
    let stash_keys: Vec<_> = rec
        .ledger
        .tensors
        .keys()
        .filter(|(name, _)| name.starts_with("stash:"))
        .collect();
    assert!(!stash_keys.is_empty(), "no stash:* ledger entries");
    // grouping: the Table-1 compute mix must ignore stash records entirely
    let mix = apt::exp::common::grad_mix_string(&rec.ledger);
    let stash_mix = apt::exp::common::stash_mix_string(&rec.ledger);
    assert!(mix.contains("int8") && stash_mix.contains("int8"));
}

// ------------------------------------------------------------- checkpoints

#[test]
fn checkpoint_v3_roundtrips_stash_controllers_bit_identically() {
    let build = || {
        SessionBuilder::classifier("mlp")
            .mode(QuantMode::Static(8))
            .stash_policy(adaptive_stash(3))
            .build()
    };
    let path = ckpt_path("v3_roundtrip");
    let mut a = build();
    a.run(8).unwrap();
    a.save_checkpoint(&path).unwrap();

    // the file is v3 and carries the stash section
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.iters_done(), 8);
    assert!(
        !ck.stash_controllers().is_empty(),
        "adaptive-stash save lost its controllers"
    );

    let mut b = build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), 8);
    a.run(6).unwrap();
    b.run(6).unwrap();
    assert_eq!(a.losses(), b.losses(), "restored run diverged");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_stash_policy_mismatch_rejected_without_mutation() {
    let path = ckpt_path("v3_mismatch");
    let mut a = SessionBuilder::classifier("mlp")
        .stash_policy(adaptive_stash(2))
        .build();
    a.run(5).unwrap();
    a.save_checkpoint(&path).unwrap();

    // an int8-stash session cannot host adaptive stash controllers
    let mut b = SessionBuilder::classifier("mlp")
        .stash_policy(StashPolicy::Int8)
        .build();
    let id = b.params()[0].id.clone();
    let before = b.param_copy(&id);
    assert!(b.load_checkpoint(&path).is_err());
    let after = b.param_copy(&id);
    assert_eq!(before, after, "failed restore must not mutate the session");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn host_sessions_without_adaptive_stash_write_empty_stash_section() {
    let path = ckpt_path("v3_empty_stash");
    let mut a = SessionBuilder::classifier("mlp").build();
    a.run(4).unwrap();
    a.save_checkpoint(&path).unwrap();
    let ck = Checkpoint::read(&path).unwrap();
    assert!(ck.stash_controllers().is_empty());
    // …and loads into any policy, including adaptive
    let mut b = SessionBuilder::classifier("mlp")
        .stash_policy(adaptive_stash(2))
        .build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), 4);
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------- fixtures

/// The committed fixtures were written against this exact configuration:
/// a single `fc0: Linear(4 → 3)` over a 3-class 1×2×2 synthetic stream.
fn fixture_builder(mode: QuantMode) -> SessionBuilder {
    SessionBuilder::custom("fixture-net", move |rng| {
        Sequential::new(vec![Box::new(Linear::new("fc0", 4, 3, mode, rng))])
    })
    .data(Box::new(SynthImages::new(11, 3, 1, 2, 2, 0.3)))
    .eval_set(999, 12)
}

#[test]
fn v1_fixture_checkpoint_still_loads() {
    let path = fixture("host_f32_v1.ckpt");
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.iters_done(), 3);
    assert_eq!(ck.optimizer(), "sgd");
    assert!(ck.comm_controllers().is_empty());
    assert!(ck.stash_controllers().is_empty());

    let mut s = fixture_builder(QuantMode::Float32).build();
    s.load_checkpoint(&path).unwrap();
    assert_eq!(s.iters_done(), 3);
    assert_eq!(s.losses().len(), 3);
    // the fixture's parameters were applied verbatim
    let id = s.params()[0].id.clone();
    let w = s.param_copy(&id);
    assert_eq!(w.data[0], 0.05);
    assert_eq!(w.data[1], -0.1);
    // and the run continues
    s.run(2).unwrap();
    assert!(s.losses().iter().all(|l| l.is_finite()));
    assert_eq!(s.iters_done(), 5);
}

#[test]
fn v2_fixture_checkpoint_still_loads_with_controllers() {
    let path = fixture("host_int8_v2.ckpt");
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.iters_done(), 3);
    assert!(ck.stash_controllers().is_empty(), "v2 has no stash section");

    let mut s = fixture_builder(QuantMode::Static(8)).build();
    s.load_checkpoint(&path).unwrap();
    // the compute controllers resumed the fixture's schemes
    let mut schemes = Vec::new();
    s.net_mut().visit_controllers(&mut |_, lc| {
        schemes.push((lc.w.scheme(), lc.x.scheme(), lc.g.scheme()));
    });
    assert_eq!(schemes.len(), 1);
    assert_eq!((schemes[0].0.bits, schemes[0].0.s), (8, -9));
    assert_eq!((schemes[0].1.bits, schemes[0].1.s), (8, -5));
    assert_eq!((schemes[0].2.bits, schemes[0].2.s), (8, -12));

    s.run(2).unwrap();
    assert!(s.losses().iter().all(|l| l.is_finite()));
    let rec = s.record().unwrap();
    // the v2 ledger came through: 2 events + the clamp at iter 2
    let hist = &rec.ledger.tensors
        [&("fc0".to_string(), apt::fixedpoint::TensorKind::Gradient)];
    assert_eq!(hist.events.len(), 2);
    assert_eq!(hist.clamps, vec![2]);
}

// ------------------------------------------------------------------- rnn

#[test]
fn seq2seq_backend_trains_under_quantized_stash() {
    use apt::train::{Seq2SeqBackend, Session};
    let mut b = Seq2SeqBackend::new("rnn-i8stash", 12, 16, QuantMode::Float32, 0, 8, 4, 0.05, 32);
    b.set_stash(StashPolicy::Int8, false);
    let mut s = Session::with_backend(b);
    s.run(25).unwrap();
    assert!(s.backend().stash().mem().peak_bytes() > 0, "BPTT never stashed");
    let rec = s.record().unwrap();
    assert!(rec.losses.iter().all(|l| l.is_finite()));
    assert!(
        rec.losses.last().unwrap() < &(rec.losses[0] * 1.2),
        "int8-stash BPTT diverged: {:?} → {:?}",
        rec.losses[0],
        rec.losses.last()
    );
}
