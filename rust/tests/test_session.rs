//! `train::Session` contract tests:
//!
//! 1. **Loop parity** — a seeded `Session` run reproduces the pre-refactor
//!    `exp::common::train_classifier` loop (replicated inline here, fused
//!    SGD and all) bit-identically: loss curve, eval accuracy, parameters.
//!    Covers mlp/alexnet × Float32 / Static(8) / Static(16) / Adaptive —
//!    which is simultaneously the optimizer-parity guarantee for the
//!    `Optimizer`-trait SGD against the old fused `Sgd`.
//! 2. **Checkpoint round-trip** — save mid-run (params, optimizer state,
//!    controller state, ledger, data stream), restore into a fresh
//!    `Session`, and the continued iterations are bit-identical to an
//!    uninterrupted run.

use apt::apt::AptConfig;
use apt::data::SynthImages;
use apt::nn::loss::{accuracy, softmax_xent};
use apt::nn::{models, QuantMode, TrainCtx};
use apt::train::SessionBuilder;
use apt::util::Pcg32;

fn adaptive(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

/// The pre-refactor `train_classifier` loop, verbatim: seeded RNG → model →
/// data(seed+1000) → per-iter forward/loss/backward → *fused* SGD-momentum
/// update that zeroes gradients in the same pass → eval on stream 999.
fn reference_train(
    model: &str,
    mode: QuantMode,
    iters: u64,
    lr: f32,
) -> (Vec<f32>, f64, Vec<Vec<f32>>) {
    let (batch, seed, noise) = (16usize, 0u64, 0.5f32);
    let mut rng = Pcg32::seeded(seed);
    let mut net = models::by_name(model, mode, &mut rng).expect("model");
    let mut data = SynthImages::new(
        seed + 1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        noise,
    );
    let mut velocity: Vec<Vec<f32>> = Vec::new();
    let mut ctx = TrainCtx::new();
    let mut losses = Vec::with_capacity(iters as usize);
    for it in 0..iters {
        ctx.iter = it;
        let (x, y) = data.batch(batch);
        let logits = net.forward(&x, &mut ctx);
        let (l, g) = softmax_xent(&logits, &y);
        net.backward(&g, &mut ctx);
        let mut idx = 0usize;
        net.visit_params(&mut |p, gr| {
            if velocity.len() <= idx {
                velocity.push(vec![0.0; p.len()]);
            }
            let v = &mut velocity[idx];
            for ((pv, gv), vv) in p.data.iter_mut().zip(gr.data.iter_mut()).zip(v.iter_mut()) {
                *vv = 0.9 * *vv + *gv;
                *pv -= lr * *vv;
                *gv = 0.0;
            }
            idx += 1;
        });
        losses.push(l);
    }
    ctx.ledger.set_total_iters(iters);
    ctx.training = false;
    let (ex, ey) = data.eval_set(999, 256);
    let logits = net.forward(&ex, &mut ctx);
    let acc = accuracy(&logits, &ey);
    let mut params = Vec::new();
    net.visit_params(&mut |p, _| params.push(p.data.clone()));
    (losses, acc, params)
}

fn assert_session_matches_reference(model: &str, mode: QuantMode, iters: u64, lr: f32) {
    let (ref_losses, ref_acc, ref_params) = reference_train(model, mode, iters, lr);
    let mut s = SessionBuilder::classifier(model).mode(mode).lr(lr).build();
    s.run(iters).unwrap();
    let eval = s.eval().unwrap();
    assert_eq!(
        s.losses(),
        &ref_losses[..],
        "{model}/{}: loss curve diverged from the pre-refactor loop",
        mode.label()
    );
    assert_eq!(
        eval.accuracy,
        ref_acc,
        "{model}/{}: eval accuracy diverged",
        mode.label()
    );
    let mut params = Vec::new();
    s.net_mut().visit_params(&mut |p, _| params.push(p.data.clone()));
    assert_eq!(params.len(), ref_params.len());
    for (i, (a, b)) in params.iter().zip(&ref_params).enumerate() {
        assert_eq!(a, b, "{model}/{}: parameter {i} diverged", mode.label());
    }
}

#[test]
fn session_reproduces_reference_mlp_all_modes() {
    let iters = 40;
    for mode in [
        QuantMode::Float32,
        QuantMode::Static(8),
        QuantMode::Static(16),
        adaptive(iters),
    ] {
        assert_session_matches_reference("mlp", mode, iters, 0.02);
    }
}

#[test]
fn session_reproduces_reference_alexnet_all_modes() {
    let iters = 20;
    for mode in [
        QuantMode::Float32,
        QuantMode::Static(8),
        QuantMode::Static(16),
        adaptive(iters),
    ] {
        assert_session_matches_reference("alexnet", mode, iters, 0.01);
    }
}

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_ckpt_{tag}_{}.txt", std::process::id()))
}

fn roundtrip(model: &str, mode: QuantMode, pre: u64, post: u64) {
    let build = || SessionBuilder::classifier(model).mode(mode).build();
    let path = ckpt_path(model);

    // uninterrupted run: pre + post iterations
    let mut a = build();
    a.run(pre).unwrap();
    a.save_checkpoint(&path).unwrap();
    a.run(post).unwrap();

    // fresh session, restored mid-run, continued
    let mut b = build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), pre);
    assert_eq!(b.losses(), &a.losses()[..pre as usize]);
    b.run(post).unwrap();

    assert_eq!(
        b.losses(),
        a.losses(),
        "{model}: restored run's losses diverged from the uninterrupted run"
    );
    let (ea, eb) = (a.eval().unwrap(), b.eval().unwrap());
    assert_eq!(ea.accuracy, eb.accuracy, "{model}: eval diverged after restore");

    // parameters and ledger must agree exactly
    let mut pa = Vec::new();
    let mut pb = Vec::new();
    a.net_mut().visit_params(&mut |p, _| pa.push(p.data.clone()));
    b.net_mut().visit_params(&mut |p, _| pb.push(p.data.clone()));
    assert_eq!(pa, pb, "{model}: parameters diverged after restore");

    let (ra, rb) = (a.record().unwrap(), b.record().unwrap());
    assert_eq!(ra.ledger.total_updates(), rb.ledger.total_updates());
    assert_eq!(ra.ledger.tensors.len(), rb.ledger.tensors.len());
    for (((na, ka), ha), ((nb, kb), hb)) in
        ra.ledger.tensors.iter().zip(rb.ledger.tensors.iter())
    {
        assert_eq!((na, ka), (nb, kb));
        assert_eq!(ha.events.len(), hb.events.len(), "{na}: event count");
        for (x, y) in ha.events.iter().zip(&hb.events) {
            assert_eq!((x.iter, x.bits, x.interval), (y.iter, y.bits, y.interval), "{na}");
            assert_eq!(x.error.to_bits(), y.error.to_bits(), "{na}: event error");
        }
        assert_eq!(ha.bits_trace, hb.bits_trace, "{na}: bits trace");
    }

    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_roundtrip_mlp_adaptive() {
    // Adaptive mode exercises the full state surface: controllers mid-
    // interval, ledger events, optimizer velocity, data-stream RNG.
    roundtrip("mlp", adaptive(20), 10, 10);
}

#[test]
fn checkpoint_roundtrip_resnet_adaptive() {
    // ResNet adds nested-block controllers and batch-norm running stats.
    roundtrip("resnet", adaptive(12), 6, 6);
}

#[test]
fn checkpoint_rejects_optimizer_mismatch() {
    let path = ckpt_path("mismatch");
    let mut a = SessionBuilder::classifier("mlp").build();
    a.run(3).unwrap();
    a.save_checkpoint(&path).unwrap();
    let mut b = SessionBuilder::classifier("mlp").adam().build();
    let err = b.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("optimizer"), "unexpected error: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_rejects_architecture_mismatch() {
    let path = ckpt_path("arch");
    let mut a = SessionBuilder::classifier("mlp").build();
    a.run(2).unwrap();
    a.save_checkpoint(&path).unwrap();
    let mut b = SessionBuilder::classifier("alexnet").build();
    assert!(b.load_checkpoint(&path).is_err());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn adam_session_trains() {
    let run = SessionBuilder::classifier("mlp").adam().lr(0.005).train(60);
    let first: f64 = run.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(run.tail_loss(5) < first, "adam failed to reduce loss");
    assert!(run.eval_acc > 0.15, "acc={}", run.eval_acc);
}
