//! Cross-language oracle check: the Rust `fixedpoint` scheme must be
//! bit-identical to the Python reference (`python/compile/kernels/ref.py`)
//! that pins the Pallas kernels. Shells out to the same Python interpreter
//! used by `make artifacts`; skips if Python/numpy are unavailable.

use apt::fixedpoint::quantize::max_abs;
use apt::fixedpoint::Scheme;
use apt::util::Pcg32;
use std::process::Command;

fn python_fake_quant(xs: &[f32], bits: u8) -> Option<(Vec<f32>, f64, f64)> {
    // emits: r qmin qmax then the quantized values, one per line
    let script = r#"
import sys, math
import numpy as np
sys.path.insert(0, "python")
from compile.kernels import ref
xs = np.array([float(t) for t in sys.argv[2].split(",")], dtype=np.float32)
bits = int(sys.argv[1])
r, qmin, qmax = ref.scheme_params(float(np.abs(xs).max()), bits)
xq = ref.np_fake_quant(xs, r, qmin, qmax)
diff = ref.np_qem_diff(xs, r, qmin, qmax)
print(r, qmin, qmax, diff)
for v in xq:
    print(repr(float(v)))
"#;
    let csv: Vec<String> = xs.iter().map(|v| format!("{v}")).collect();
    let out = Command::new("python")
        .args(["-c", script, &bits.to_string(), &csv.join(",")])
        .output()
        .ok()?;
    if !out.status.success() {
        eprintln!("python failed: {}", String::from_utf8_lossy(&out.stderr));
        return None;
    }
    let text = String::from_utf8(out.stdout).ok()?;
    let mut lines = text.lines();
    let head: Vec<f64> = lines
        .next()?
        .split_whitespace()
        .map(|t| t.parse().unwrap())
        .collect();
    let vals: Vec<f32> = lines.map(|l| l.trim().parse().unwrap()).collect();
    Some((vals, head[0], head[3]))
}

#[test]
fn rust_scheme_bit_identical_to_python_ref() {
    let mut rng = Pcg32::seeded(2024);
    let xs: Vec<f32> = (0..64).map(|_| rng.normal() * 3.0).collect();
    for bits in [8u8, 16, 24] {
        let Some((py_vals, py_r, py_diff)) = python_fake_quant(&xs, bits) else {
            eprintln!("SKIP: python oracle unavailable");
            return;
        };
        let sch = Scheme::for_range(max_abs(&xs), bits);
        assert!(
            (sch.resolution() as f64 - py_r).abs() < 1e-12,
            "bits={bits}: r {} vs python {py_r}",
            sch.resolution()
        );
        for (i, (&x, &py)) in xs.iter().zip(&py_vals).enumerate() {
            let rs = sch.fake_quant(x);
            assert_eq!(rs, py, "bits={bits} elem {i}: rust {rs} vs python {py} (x={x})");
        }
        let st = apt::fixedpoint::quantize::stats_only(&xs, sch);
        // numpy sums |x| in f32 (pairwise); Rust accumulates f64 — the Diff
        // summary may differ at ~1e-8 even though every value is bit-equal.
        assert!(
            (st.diff() - py_diff).abs() < 1e-6,
            "bits={bits}: Diff {} vs python {py_diff}",
            st.diff()
        );
    }
}

#[test]
fn rust_scheme_handles_extreme_magnitudes_like_python() {
    for &scale in &[1e-20f32, 1e-3, 1e6, 1e20] {
        let xs: Vec<f32> = vec![scale, -scale / 2.0, scale / 3.0, 0.0];
        let Some((py_vals, _, _)) = python_fake_quant(&xs, 8) else {
            eprintln!("SKIP: python oracle unavailable");
            return;
        };
        let sch = Scheme::for_range(max_abs(&xs), 8);
        for (i, (&x, &py)) in xs.iter().zip(&py_vals).enumerate() {
            assert_eq!(sch.fake_quant(x), py, "scale={scale} elem {i} (x={x})");
        }
    }
}
