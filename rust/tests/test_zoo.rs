//! First integration coverage for the zoo's task heads — detection
//! (SSD-lite) and segmentation (deeplab-lite) — plus their hookup into the
//! calibration subsystem: forward/backward shape contracts, int8-vs-f32
//! convergence smoke, PTQ observer sites over the conv trunks, and a
//! calibrated freeze of the segmentation net (DESIGN.md §Calibration).

use apt::calib::{Calibrator, ObserverKind};
use apt::compiler::CompileOptions;
use apt::data::{SynthDetection, SynthSegmentation};
use apt::fixedpoint::FormatFamily;
use apt::nn::models::{DetectionNet, SegNet};
use apt::nn::{QuantMode, TrainCtx};
use apt::serve::FrozenModel;
use apt::util::Pcg32;

const CLASSES: usize = 3;

// ---------------------------------------------------------------- detection

#[test]
fn detection_forward_shapes_and_finite_backward() {
    let mut rng = Pcg32::seeded(7);
    let mut net = DetectionNet::new(CLASSES, QuantMode::Float32, &mut rng);
    let mut data = SynthDetection::new(3, CLASSES, 3, 16, 16);
    let mut ctx = TrainCtx::new();
    let (x, gt_boxes, gt_classes) = data.batch(8);

    let (boxes, logits) = net.forward(&x, &mut ctx);
    assert_eq!(boxes.shape, vec![8, 4], "box head emits [n, 4]");
    assert_eq!(logits.shape, vec![8, CLASSES], "class head emits [n, classes]");
    assert!(
        boxes.data.iter().all(|v| (0.0..=1.0).contains(v)),
        "sigmoid boxes live in [0, 1]"
    );
    assert!(logits.data.iter().all(|v| v.is_finite()), "finite class logits");

    // One full train step: losses finite, gradients actually moved weights.
    let before: Vec<f32> = net.head_cls.w.data.clone();
    let (lb, lc) = net.train_step(&x, &gt_boxes, &gt_classes, 0.05, &mut ctx);
    assert!(lb.is_finite() && lb >= 0.0, "box loss {lb}");
    assert!(lc.is_finite() && lc > 0.0, "class loss {lc}");
    assert!(
        net.head_cls.w.data.iter().zip(&before).any(|(a, b)| a != b),
        "backward/SGD must update the classification head"
    );
}

#[test]
fn detection_converges_under_int8_and_f32() {
    for (label, mode) in [("f32", QuantMode::Float32), ("int8", QuantMode::Static(8))] {
        let mut rng = Pcg32::seeded(0);
        let mut net = DetectionNet::new(CLASSES, mode, &mut rng);
        let mut data = SynthDetection::new(1, CLASSES, 3, 16, 16);
        let mut ctx = TrainCtx::new();
        let (mut first, mut last) = (0.0, 0.0);
        for it in 0..30 {
            ctx.iter = it;
            let (x, boxes, classes) = data.batch(8);
            let (lb, lc) = net.train_step(&x, &boxes, &classes, 0.05, &mut ctx);
            assert!(
                lb.is_finite() && lc.is_finite(),
                "{label}: non-finite loss at iter {it}"
            );
            if it == 0 {
                first = lb + lc;
            }
            last = lb + lc;
        }
        assert!(last < first, "{label}: detector failed to learn — first={first} last={last}");
    }
}

// ------------------------------------------------------------- segmentation

#[test]
fn segmentation_predict_shapes_and_finite_backward() {
    let mut rng = Pcg32::seeded(11);
    let mut net = SegNet::new(CLASSES, QuantMode::Float32, &mut rng);
    let mut data = SynthSegmentation::new(5, CLASSES, 3, 12, 12);
    let mut ctx = TrainCtx::new();
    let (x, labels) = data.batch(6);

    let masks = net.predict(&x, &mut ctx);
    assert_eq!(masks.len(), 6, "one mask per image");
    for mask in &masks {
        assert_eq!(mask.len(), 12 * 12, "per-pixel mask covers the full image");
        assert!(mask.iter().all(|&c| c < CLASSES), "mask classes in range");
    }

    let loss = net.train_step(&x, &labels, &mut ctx);
    assert!(loss.is_finite() && loss > 0.0, "pixel loss {loss}");

    let miou = net.eval_miou(&x, &labels, &mut ctx);
    assert!((0.0..=1.0).contains(&miou), "mIoU {miou} out of range");
}

#[test]
fn segmentation_converges_under_int8_and_f32() {
    for (label, mode) in [("f32", QuantMode::Float32), ("int8", QuantMode::Static(8))] {
        let mut rng = Pcg32::seeded(0);
        let mut net = SegNet::new(CLASSES, mode, &mut rng);
        let mut data = SynthSegmentation::new(1, CLASSES, 3, 12, 12);
        let mut ctx = TrainCtx::new();
        let (mut first, mut last) = (0.0, 0.0);
        for it in 0..25 {
            ctx.iter = it;
            let (x, labels) = data.batch(8);
            let l = net.train_step(&x, &labels, &mut ctx);
            assert!(l.is_finite(), "{label}: non-finite loss at iter {it}");
            if it == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first, "{label}: segmenter failed to learn — first={first} last={last}");
    }
}

// ------------------------------------------------- calibration over the zoo

#[test]
fn zoo_trunks_expose_calibration_sites() {
    let mut rng = Pcg32::seeded(2);

    // Detection trunk: two conv sites (pool/relu are not observation points).
    let det = DetectionNet::new(CLASSES, QuantMode::Float32, &mut rng);
    let mut cal = Calibrator::from_net("det-trunk", &det.trunk, ObserverKind::MinMax)
        .expect("detection trunk exports for observation");
    assert_eq!(cal.site_names(), vec!["det_conv0", "det_conv1"]);
    let mut data = SynthDetection::new(9, CLASSES, 3, 16, 16);
    let (x, _, _) = data.batch(16);
    cal.observe(&x);
    assert_eq!(cal.samples(), 16);
    let table = cal.finish(FormatFamily::FixedPoint, 8, false);
    assert_eq!(table.sites.len(), 2);
    for site in &table.sites {
        assert!(site.max_abs > 0.0, "{}: observed range must be positive", site.name);
        assert_eq!(site.fmt.storage_bits(), 8, "{}: int8 activation format", site.name);
    }

    // Segmentation net: conv0/conv1/head, fully convolutional.
    let seg = SegNet::new(CLASSES, QuantMode::Float32, &mut rng);
    let mut cal = Calibrator::from_net("segnet", &seg.net, ObserverKind::Percentile(99.99))
        .expect("segmentation net exports for observation");
    assert_eq!(cal.site_names(), vec!["seg_conv0", "seg_conv1", "seg_head"]);
}

#[test]
fn segnet_ptq_freeze_matches_float_masks() {
    let mut rng = Pcg32::seeded(0);
    let mut net = SegNet::new(CLASSES, QuantMode::Float32, &mut rng);
    let mut data = SynthSegmentation::new(1, CLASSES, 3, 12, 12);
    let mut ctx = TrainCtx::new();
    for it in 0..25 {
        ctx.iter = it;
        let (x, labels) = data.batch(8);
        net.train_step(&x, &labels, &mut ctx);
    }

    // PTQ: observe activations on held-out batches, then freeze the float
    // net with calibrated int8 activation formats — zero quantized training.
    let mut cal = Calibrator::from_net("segnet", &net.net, ObserverKind::MinMax).expect("observe");
    let mut eval = SynthSegmentation::new(77, CLASSES, 3, 12, 12);
    for _ in 0..4 {
        let (x, _) = eval.batch(16);
        cal.observe(&x);
    }
    let table = cal.finish(FormatFamily::FixedPoint, 8, false);
    let frozen = FrozenModel::freeze_ptq_net("segnet-ptq", &net.net, &table, &CompileOptions::default())
        .expect("calibrated freeze");

    let (x, _) = eval.batch(16);
    let float_masks = net.predict(&x, &mut ctx);
    let logits = frozen.forward(&x, apt::kernels::global());
    assert_eq!(logits.shape, vec![16, CLASSES * 12 * 12]);
    assert!(logits.data.iter().all(|v| v.is_finite()), "finite frozen logits");

    // Per-pixel argmax agreement between the int8 frozen path and the float
    // net. int8 PTQ on a trained net should track the float masks closely;
    // 0.75 leaves headroom for borderline pixels.
    let hw = 12 * 12;
    let (mut agree, mut total) = (0usize, 0usize);
    for (img, fm) in float_masks.iter().enumerate() {
        for p in 0..hw {
            let mut best = f32::NEG_INFINITY;
            let mut cls = 0usize;
            for c in 0..CLASSES {
                let v = logits.data[img * CLASSES * hw + c * hw + p];
                if v > best {
                    best = v;
                    cls = c;
                }
            }
            agree += (cls == fm[p]) as usize;
            total += 1;
        }
    }
    let frac = agree as f64 / total as f64;
    assert!(frac >= 0.75, "PTQ masks diverged from float masks: agreement {frac:.3}");
}
