//! Calibration-subsystem gate (DESIGN.md §Calibration):
//!
//! 1. **PTQ acceptance** — alexnet trained *float*, frozen through
//!    `FrozenModel::freeze_ptq` with percentile-calibrated int8 activation
//!    formats, must agree with the float `Session::eval` path on ≥ 98% of
//!    eval top-1 predictions — and the whole pipeline works with zero
//!    training steps (quantization entirely post hoc).
//! 2. **Schedule pins** — `Schedule::delay(0)` and a single-phase
//!    progressive schedule at the controllers' existing width are
//!    bit-identical to the pre-schedule controller path; a multi-phase
//!    schedule actually retunes the live widths at its boundaries.
//! 3. **Checkpoint `calib` section** — tables embed into checkpoints,
//!    survive re-reads, replace on re-write, and never disturb the weight
//!    payload a session restores from.

use apt::calib::{Calibrator, ObserverKind, Schedule};
use apt::compiler::CompileOptions;
use apt::data::SynthImages;
use apt::fixedpoint::FormatFamily;
use apt::nn::{models, QuantMode, Sequential};
use apt::serve::{FrozenModel, InferOp};
use apt::train::checkpoint::Checkpoint;
use apt::train::SessionBuilder;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_test_calib_{name}_{}.ckpt", std::process::id()))
}

fn synth(seed: u64) -> SynthImages {
    SynthImages::new(seed, models::CLASSES, models::IN_C, models::IN_H, models::IN_W, 0.5)
}

// ---------------------------------------------------------- PTQ acceptance

#[test]
fn ptq_alexnet_agrees_with_float_eval_top1() {
    // Train alexnet purely in f32 — no quantization anywhere in training.
    let mut s = SessionBuilder::classifier("alexnet").mode(QuantMode::Float32).lr(0.01).build();
    s.run(80).expect("float training");
    let ckpt = tmp("alexnet_ptq");
    s.save_checkpoint(&ckpt).expect("save float checkpoint");

    // Calibrate int8 activation formats from observed statistics alone.
    let mut cal = Calibrator::from_net("alexnet", s.net(), ObserverKind::Percentile(99.99))
        .expect("observation program");
    let mut data = synth(4242);
    while cal.samples() < 256 {
        let (x, _) = data.batch(32);
        cal.observe(&x);
    }
    let table = cal.finish(FormatFamily::FixedPoint, 8, false);
    assert_eq!(table.samples, 256);
    assert!(table.sites.iter().all(|site| site.max_abs > 0.0));

    // Freeze the float checkpoint with the calibrated formats.
    let frozen = FrozenModel::freeze_ptq(&ckpt, "alexnet", &table, &CompileOptions::default())
        .expect("calibrated freeze");

    // ≥ 98% top-1 agreement with the float eval path (the ISSUE pin).
    let (ex, _) = data.eval_set(999, 256);
    let want = s.eval_logits(&ex).argmax_rows();
    let got = frozen.forward(&ex, apt::kernels::global()).argmax_rows();
    let agree = want.iter().zip(&got).filter(|(a, b)| a == b).count();
    let frac = agree as f64 / want.len() as f64;
    assert!(frac >= 0.98, "PTQ int8 top-1 agreement {frac:.4} < 0.98 vs float eval");
    let _ = std::fs::remove_file(&ckpt);
}

#[test]
fn ptq_freeze_works_with_zero_training_steps() {
    // Checkpoint straight out of the initializer: PTQ must not depend on
    // any training having happened.
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Float32).build();
    let ckpt = tmp("mlp_zero_step");
    s.save_checkpoint(&ckpt).expect("save untrained checkpoint");

    let mut cal =
        Calibrator::from_net("mlp", s.net(), ObserverKind::MinMax).expect("observation program");
    let mut data = synth(7);
    let (x, _) = data.batch(32);
    cal.observe(&x);
    let table = cal.finish(FormatFamily::FixedPoint, 8, false);
    let frozen = FrozenModel::freeze_ptq(&ckpt, "mlp", &table, &CompileOptions::default())
        .expect("calibrated freeze of an untrained checkpoint");

    let y = frozen.forward(&x, apt::kernels::global());
    assert_eq!(y.shape, vec![32, models::CLASSES]);
    assert!(y.data.iter().all(|v| v.is_finite()), "finite logits from the zero-step freeze");
    let _ = std::fs::remove_file(&ckpt);
}

// ------------------------------------------------------------ schedule pins

#[test]
fn degenerate_schedules_are_bit_identical_to_the_controller_path() {
    let base = SessionBuilder::classifier("mlp").mode(QuantMode::Static(8)).train(12);

    // delay:0 — the historical default, spelled through the new axis.
    let d0 = SessionBuilder::classifier("mlp")
        .mode(QuantMode::Static(8))
        .schedule(Schedule::delay(0))
        .train(12);
    for (i, (a, b)) in base.losses.iter().zip(&d0.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "delay:0 loss {i} diverged");
    }

    // A single phase at the controllers' existing width retunes nothing.
    let single = SessionBuilder::classifier("mlp")
        .mode(QuantMode::Static(8))
        .schedule(Schedule::parse("progressive:8@0", 12).unwrap())
        .train(12);
    for (i, (a, b)) in base.losses.iter().zip(&single.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "progressive:8@0 loss {i} diverged");
    }
}

/// Live weight/activation widths as the serving export would freeze them.
fn live_widths(net: &Sequential) -> Vec<u8> {
    net.export_infer()
        .expect("classifier nets export")
        .iter()
        .filter_map(|op| match op {
            InferOp::Linear { sw: Some(f), .. } => Some(f.storage_bits()),
            InferOp::Conv { sw: Some(f), .. } => Some(f.storage_bits()),
            InferOp::Depthwise { sw: Some(f), .. } => Some(f.storage_bits()),
            _ => None,
        })
        .collect()
}

#[test]
fn progressive_schedule_retunes_widths_at_phase_boundaries() {
    let sched = Schedule::parse("progressive:16@0,8@6", 20).unwrap();
    let mut s = SessionBuilder::classifier("mlp")
        .mode(QuantMode::Static(16))
        .schedule(sched)
        .build();

    s.run(4).expect("first phase");
    let w = live_widths(s.net());
    assert!(!w.is_empty(), "static session exposes quantized sites");
    assert!(w.iter().all(|&b| b == 16), "mid-first-phase widths {w:?} should be 16");

    s.run(8).expect("across the 8@6 boundary");
    let w = live_widths(s.net());
    assert!(w.iter().all(|&b| b == 8), "post-boundary widths {w:?} should be 8");
    assert!(s.losses().iter().all(|l| l.is_finite()), "finite losses across the retune");
}

// -------------------------------------------------- checkpoint calib section

#[test]
fn checkpoint_calib_section_round_trips_and_replaces() {
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Float32).build();
    s.run(4).expect("short float run");
    let ckpt = tmp("mlp_calib_section");
    s.save_checkpoint(&ckpt).expect("save");
    assert!(
        Checkpoint::read(&ckpt).expect("read").calib_table().is_none(),
        "fresh checkpoints carry no calib section"
    );

    let mut cal =
        Calibrator::from_net("mlp", s.net(), ObserverKind::MinMax).expect("observation program");
    let mut data = synth(31);
    let (x, _) = data.batch(16);
    cal.observe(&x);

    // Embed, re-read, compare bit-exactly.
    let table = cal.finish(FormatFamily::FixedPoint, 8, false);
    Checkpoint::write_calib(&ckpt, &table).expect("embed calib section");
    let back = Checkpoint::read(&ckpt).expect("re-read");
    assert_eq!(back.calib_table(), Some(&table));

    // Re-embedding replaces the section rather than stacking a second one.
    let table2 = cal.finish(FormatFamily::FixedPoint, 4, true);
    assert_ne!(table, table2);
    Checkpoint::write_calib(&ckpt, &table2).expect("replace calib section");
    assert_eq!(Checkpoint::read(&ckpt).expect("re-read").calib_table(), Some(&table2));

    // The weight payload is untouched: a fresh session restored from the
    // annotated checkpoint evaluates bit-identically to the live one.
    let mut restored = SessionBuilder::classifier("mlp").mode(QuantMode::Float32).build();
    restored.load_checkpoint(&ckpt).expect("restore annotated checkpoint");
    let (ex, _) = data.batch(8);
    let a = s.eval_logits(&ex);
    let b = restored.eval_logits(&ex);
    assert_eq!(a.shape, b.shape);
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "logit {i} diverged after calib embed + restore");
    }
    let _ = std::fs::remove_file(&ckpt);
}
