//! Data-parallel training contract tests (DESIGN.md §Data-Parallel):
//!
//! 1. **Single-replica parity** — `build_parallel(1, _)` is bit-identical
//!    to the plain host `Session` loop for every comm precision *and every
//!    compression policy* (nothing is communicated at N = 1; that is the
//!    documented exactness condition).
//! 2. **Tree-reduction oracle** — at N ∈ {2, 4} flat and N ∈ {8, 16}
//!    hierarchical (node 4) with f32 comm, the loss and parameter
//!    trajectories match the independent shard → backward → fixed-order
//!    tree reduction → shared SGD oracle (`tests/common/oracle.rs`)
//!    bit-exactly.
//! 3. **Node-size invariance** — for every compressor policy the
//!    hierarchical node size changes bytes-on-wire accounting only, never
//!    the trained result (the `hier_reduce_f32` lemma for f32 payloads,
//!    exact i64 code summation for quantized ones).
//! 4. **Quantized/compressed-comm convergence** — int8 and
//!    topk+quantize gradient exchange still train the tier-1 mlp/alexnet
//!    configs.
//! 5. **Sync invariant** — replicas hold bit-identical parameters after
//!    any number of steps, under quantized compute and comm.
//! 6. **Typed input rejection** — malformed per-replica gradient lists
//!    fail with a [`ReduceError`] instead of a silently wrong average.
//! 7. **Checkpoint round-trip** — communication controllers *and*
//!    error-feedback residuals resume bit-identically; policy mismatches
//!    and residual-less artifacts are rejected read-only; the committed
//!    v1 (host) and v3 (parallel top-k) fixtures keep loading.

mod common;

use apt::apt::AptConfig;
use apt::data::SynthImages;
use apt::nn::linear::Linear;
use apt::nn::{QuantMode, Sequential};
use apt::train::checkpoint::Checkpoint;
use apt::train::parallel::QuantAllReduce;
use apt::train::{CommPrecision, CompressPolicy, ReduceError, SessionBuilder};
use common::oracle::oracle_parallel;

fn adaptive(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

fn comm_adaptive(iters: u64) -> CommPrecision {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    CommPrecision::Adaptive(cfg)
}

/// The four (comm precision, compression policy) corners of the seam, for
/// the tests that must hold under *every* compressor.
fn policy_corners() -> Vec<(CommPrecision, CompressPolicy)> {
    vec![
        (CommPrecision::F32, CompressPolicy::None),
        (CommPrecision::Static(8), CompressPolicy::Quantize),
        (CommPrecision::F32, CompressPolicy::TopK(0.25)),
        (CommPrecision::Static(8), CompressPolicy::TopKQuantize(0.25)),
    ]
}

// ---------------------------------------------------------------- parity

fn assert_replicas_one_matches_host(
    mode: QuantMode,
    comm: CommPrecision,
    policy: CompressPolicy,
    iters: u64,
) {
    let mut host = SessionBuilder::classifier("mlp").mode(mode).build();
    host.run(iters).unwrap();
    let mut par = SessionBuilder::classifier("mlp")
        .mode(mode)
        .compress(policy)
        .build_parallel(1, comm)
        .unwrap();
    par.run(iters).unwrap();

    let label = policy.label();
    assert_eq!(host.losses(), par.losses(), "loss trajectories diverged at N=1 ({label})");
    let (ha, pa) = (host.eval().unwrap(), par.eval().unwrap());
    assert_eq!(ha.accuracy, pa.accuracy, "eval diverged at N=1 ({label})");
    let mut hp = Vec::new();
    let mut pp = Vec::new();
    host.net_mut().visit_params(&mut |p, _| hp.push(p.data.clone()));
    par.net_mut().visit_params(&mut |p, _| pp.push(p.data.clone()));
    assert_eq!(hp, pp, "parameters diverged at N=1 ({label})");
}

#[test]
fn replicas_one_bit_identical_to_host_loop() {
    // The comm policy must be irrelevant at N = 1 — int8 codes never touch
    // the gradients because there is nothing to exchange.
    let iters = 25;
    let f32c = CompressPolicy::None;
    let q = CompressPolicy::Quantize;
    assert_replicas_one_matches_host(QuantMode::Float32, CommPrecision::F32, f32c, iters);
    assert_replicas_one_matches_host(QuantMode::Float32, CommPrecision::Static(8), q, iters);
    assert_replicas_one_matches_host(adaptive(iters), CommPrecision::Static(8), q, iters);
}

#[test]
fn replicas_one_bit_identical_for_every_compressor_policy() {
    // Identity, quantize, top-k and the composition are all inert at N=1:
    // the group short-circuits to the host step before any payload exists.
    for (comm, policy) in policy_corners() {
        assert_replicas_one_matches_host(QuantMode::Float32, comm, policy, 10);
    }
}

// ------------------------------------------------------ tree-reduce oracle

fn assert_f32_comm_matches_oracle(mode: QuantMode, replicas: usize, node: usize, iters: u64) {
    let lr = 0.02;
    let (oracle_losses, oracle_params) = oracle_parallel("mlp", mode, replicas, iters, lr);
    let mut s = SessionBuilder::classifier("mlp")
        .mode(mode)
        .lr(lr)
        .node_size(node)
        .build_parallel(replicas, CommPrecision::F32)
        .unwrap();
    s.run(iters).unwrap();
    assert_eq!(
        s.losses(),
        &oracle_losses[..],
        "N={replicas} node={node}: loss curve diverged from the tree-reduction oracle"
    );
    let mut params = Vec::new();
    s.net_mut().visit_params(&mut |p, _| params.push(p.data.clone()));
    assert_eq!(params.len(), oracle_params.len());
    for (i, (a, b)) in params.iter().zip(&oracle_params).enumerate() {
        assert_eq!(a, b, "N={replicas} node={node}: parameter {i} diverged from the oracle");
    }
}

#[test]
fn f32_comm_matches_tree_oracle_two_replicas() {
    assert_f32_comm_matches_oracle(QuantMode::Float32, 2, 1, 15);
}

#[test]
fn f32_comm_matches_tree_oracle_four_replicas() {
    assert_f32_comm_matches_oracle(QuantMode::Float32, 4, 1, 15);
}

#[test]
fn f32_comm_matches_tree_oracle_quantized_compute() {
    // Quantized *compute* (per-replica QEM/QPA inside the layers) with f32
    // *comm* still matches the oracle: the controllers are deterministic
    // functions of each replica's shard.
    assert_f32_comm_matches_oracle(QuantMode::Static(8), 2, 1, 12);
}

#[test]
fn f32_comm_matches_tree_oracle_eight_replicas_hierarchical() {
    // The oracle reduces with the *flat* ladder; the session reduces
    // two-level with node 4 — bit-equal by the hier_reduce_f32 lemma.
    assert_f32_comm_matches_oracle(QuantMode::Float32, 8, 4, 10);
}

#[test]
fn f32_comm_matches_tree_oracle_sixteen_replicas_hierarchical() {
    assert_f32_comm_matches_oracle(QuantMode::Float32, 16, 4, 8);
}

#[test]
fn node_size_never_changes_the_trained_result() {
    // For every compressor policy, N=8 trained flat (node 1) and
    // hierarchically (node 4) must be bit-identical — the node size is an
    // accounting boundary, not a numeric one.
    for (comm, policy) in policy_corners() {
        let run = |node: usize| {
            let mut s = SessionBuilder::classifier("mlp")
                .lr(0.02)
                .compress(policy)
                .node_size(node)
                .build_parallel(8, comm)
                .unwrap();
            s.run(6).unwrap();
            let mut params = Vec::new();
            s.net_mut().visit_params(&mut |p, _| params.push(p.data.clone()));
            (s.losses().to_vec(), params)
        };
        let (l1, p1) = run(1);
        let (l4, p4) = run(4);
        let label = policy.label();
        assert_eq!(l1, l4, "losses diverged across node sizes ({label})");
        assert_eq!(p1, p4, "parameters diverged across node sizes ({label})");
    }
}

// ------------------------------------------------------------ convergence

#[test]
fn int8_comm_converges_mlp() {
    let iters = 60;
    let rec = {
        let mut s = SessionBuilder::classifier("mlp")
            .mode(adaptive(iters))
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap();
        s.run(iters).unwrap();
        s.record().unwrap()
    };
    let first: f64 = rec.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(
        rec.tail_loss(10) < first * 0.8,
        "int8 comm failed to train mlp: first {first:.4} tail {:.4}",
        rec.tail_loss(10)
    );
    assert!(rec.eval_acc > 0.15, "acc={}", rec.eval_acc); // better than chance
    // the communication controllers actually ran at int8
    assert!(!rec.grad_bits.is_empty());
    assert!(rec.grad_bits.iter().all(|(n, b)| n.starts_with("comm:") && *b == 8));
}

#[test]
fn int8_comm_converges_alexnet() {
    let iters = 25;
    let rec = {
        let mut s = SessionBuilder::classifier("alexnet")
            .mode(adaptive(iters))
            .lr(0.01)
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap();
        s.run(iters).unwrap();
        s.record().unwrap()
    };
    let first: f64 = rec.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(
        rec.tail_loss(5) < first,
        "int8 comm failed to reduce alexnet loss: first {first:.4} tail {:.4}",
        rec.tail_loss(5)
    );
}

#[test]
fn topk_quantize_comm_converges_mlp() {
    // The composed policy: top-k sparsification with error feedback on top
    // of int8 codes. The withheld mass is fed back, so the trajectory still
    // descends despite 75% of each payload being dropped per step.
    let iters = 60;
    let rec = {
        let mut s = SessionBuilder::classifier("mlp")
            .mode(adaptive(iters))
            .compress(CompressPolicy::TopKQuantize(0.25))
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap();
        s.run(iters).unwrap();
        s.record().unwrap()
    };
    let first: f64 = rec.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(
        rec.tail_loss(10) < first * 0.9,
        "topk+quantize comm failed to train mlp: first {first:.4} tail {:.4}",
        rec.tail_loss(10)
    );
    // the communication controllers actually ran at int8
    assert!(!rec.grad_bits.is_empty());
    assert!(rec.grad_bits.iter().all(|(n, b)| n.starts_with("comm:") && *b == 8));
}

#[test]
fn topk_quantize_comm_converges_alexnet() {
    let iters = 25;
    let rec = {
        let mut s = SessionBuilder::classifier("alexnet")
            .mode(adaptive(iters))
            .lr(0.01)
            .compress(CompressPolicy::TopKQuantize(0.25))
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap();
        s.run(iters).unwrap();
        s.record().unwrap()
    };
    let first: f64 = rec.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(
        rec.tail_loss(5) < first,
        "topk+quantize comm failed to reduce alexnet loss: first {first:.4} tail {:.4}",
        rec.tail_loss(5)
    );
}

// ----------------------------------------------------------- sync + misc

#[test]
fn replicas_stay_in_sync_under_quantized_comm() {
    let iters = 12;
    let mut s = SessionBuilder::classifier("mlp")
        .mode(adaptive(iters))
        .build_parallel(4, comm_adaptive(iters))
        .unwrap();
    s.run(iters).unwrap();
    assert!(s.replicas_in_sync(), "peer parameters diverged from the root replica");
    assert_eq!(s.replicas(), 4);
}

#[test]
fn replicas_stay_in_sync_under_topk_error_feedback() {
    // Error feedback is per-replica state, but every replica applies the
    // same reduced gradient — the sync invariant must survive it.
    let mut s = SessionBuilder::classifier("mlp")
        .compress(CompressPolicy::TopK(0.1))
        .build_parallel(4, CommPrecision::F32)
        .unwrap();
    s.run(12).unwrap();
    assert!(s.replicas_in_sync(), "peer parameters diverged under top-k comm");
}

#[test]
fn batch_must_split_evenly() {
    let err = SessionBuilder::classifier("mlp")
        .batch(10)
        .build_parallel(3, CommPrecision::F32)
        .err()
        .expect("10 across 3 replicas must be rejected");
    assert!(err.to_string().contains("split"), "unexpected error: {err}");
}

#[test]
fn incompatible_comm_and_compress_rejected_at_build() {
    // topk sends raw f32, so int8 comm is contradictory…
    let err = SessionBuilder::classifier("mlp")
        .compress(CompressPolicy::TopK(0.1))
        .build_parallel(2, CommPrecision::Static(8))
        .err()
        .expect("topk over int8 comm must be rejected");
    assert!(err.to_string().contains("--compress"), "unexpected error: {err}");
    // …and a quantizing policy cannot ride an f32 wire.
    let err = SessionBuilder::classifier("mlp")
        .compress(CompressPolicy::TopKQuantize(0.1))
        .build_parallel(2, CommPrecision::F32)
        .err()
        .expect("topk+quantize over f32 comm must be rejected");
    assert!(err.to_string().contains("--comm-bits"), "unexpected error: {err}");
}

#[test]
fn reduce_rejects_mismatched_length_gradients() {
    // Regression: mismatched per-replica tensor lengths used to be
    // silently zip-truncated; they must fail with the typed error now.
    let mut q = QuantAllReduce::new(CommPrecision::Static(8), vec!["t.0".into()]);
    let per = vec![vec![vec![1.0f32; 4]], vec![vec![2.0f32; 5]]];
    let err = q.reduce(0, &per).unwrap_err();
    assert_eq!(err, ReduceError::Length { tensor: 0, replica: 1, got: 5, want: 4 });
    assert!(err.to_string().contains("length 5"), "unexpected display: {err}");
    // and anyhow-converted through the session step machinery it stays typed
    assert!(anyhow::Error::from(err).downcast_ref::<ReduceError>().is_some());
}

// ------------------------------------------------------------ checkpoints

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_par_ckpt_{tag}_{}.txt", std::process::id()))
}

fn fixture(name: &str) -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

#[test]
fn parallel_checkpoint_roundtrip_is_bit_identical() {
    // f32 compute + adaptive int comm: every piece of state that matters —
    // params, optimizer, data RNG, and the communication controllers — is
    // in the checkpoint, so the restored run must continue bit-identically.
    let (pre, post) = (8u64, 8u64);
    let iters = pre + post;
    let build = || {
        SessionBuilder::classifier("mlp")
            .build_parallel(2, comm_adaptive(iters))
            .unwrap()
    };
    let path = ckpt_path("comm");

    let mut a = build();
    a.run(pre).unwrap();
    a.save_checkpoint(&path).unwrap();
    a.run(post).unwrap();

    let mut b = build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), pre);
    b.run(post).unwrap();

    assert_eq!(a.losses(), b.losses(), "restored run diverged");
    assert!(b.replicas_in_sync());
    let mut pa = Vec::new();
    let mut pb = Vec::new();
    a.net_mut().visit_params(&mut |p, _| pa.push(p.data.clone()));
    b.net_mut().visit_params(&mut |p, _| pb.push(p.data.clone()));
    assert_eq!(pa, pb, "parameters diverged after restore");

    // the communication controllers themselves round-tripped exactly
    let sa = a.backend().group().comm().snapshot();
    let sb = b.backend().group().comm().snapshot();
    assert_eq!(sa, sb, "communication controller state diverged");
    assert!(!sa.is_empty(), "adaptive comm must have controllers");

    let _ = std::fs::remove_file(&path);
}

#[test]
fn topk_quantize_checkpoint_roundtrip_is_bit_identical() {
    // The strongest round-trip: communication controllers *and* per-
    // (tensor, replica) error-feedback residuals must both resume for the
    // continued trajectory to be bit-identical.
    let (pre, post) = (6u64, 6u64);
    let build = || {
        SessionBuilder::classifier("mlp")
            .compress(CompressPolicy::TopKQuantize(0.25))
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap()
    };
    let path = ckpt_path("topkq");

    let mut a = build();
    a.run(pre).unwrap();
    a.save_checkpoint(&path).unwrap();

    // the saved artifact carries the compress section with every residual
    let ck = Checkpoint::read(&path).unwrap();
    let snap = ck.compress_state().expect("topk+quantize save must write compress state");
    assert_eq!(snap.label, "topk:0.25+quantize");
    assert_eq!(snap.residuals.len(), 6 * 2, "6 tensors × 2 replicas");

    a.run(post).unwrap();

    let mut b = build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(
        b.backend().group().comm().compress_snapshot(),
        *snap,
        "error-feedback residuals diverged after restore"
    );
    b.run(post).unwrap();

    assert_eq!(a.losses(), b.losses(), "restored run diverged");
    let mut pa = Vec::new();
    let mut pb = Vec::new();
    a.net_mut().visit_params(&mut |p, _| pa.push(p.data.clone()));
    b.net_mut().visit_params(&mut |p, _| pb.push(p.data.clone()));
    assert_eq!(pa, pb, "parameters diverged after restore");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn parallel_checkpoint_rejects_comm_policy_mismatch() {
    let path = ckpt_path("policy");
    let mut a = SessionBuilder::classifier("mlp")
        .build_parallel(2, CommPrecision::Static(8))
        .unwrap();
    a.run(3).unwrap();
    a.save_checkpoint(&path).unwrap();

    // f32-comm group has no controllers → restore must fail loudly,
    // and must fail *before* mutating anything (validate-then-apply).
    let mut b = SessionBuilder::classifier("mlp")
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    let mut fresh_params = Vec::new();
    b.net_mut().visit_params(&mut |p, _| fresh_params.push(p.data.clone()));
    let err = b.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("communication"), "unexpected error: {err}");
    assert_eq!(b.iters_done(), 0, "failed restore must not advance the session");
    let mut after = Vec::new();
    b.net_mut().visit_params(&mut |p, _| after.push(p.data.clone()));
    assert_eq!(fresh_params, after, "failed restore must leave parameters untouched");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn parallel_checkpoint_rejects_compress_policy_mismatch() {
    let path = ckpt_path("compress_mismatch");
    let mut a = SessionBuilder::classifier("mlp")
        .compress(CompressPolicy::TopK(0.25))
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    a.run(3).unwrap();
    a.save_checkpoint(&path).unwrap();

    // same family, different ratio → different label → rejected read-only
    let mut b = SessionBuilder::classifier("mlp")
        .compress(CompressPolicy::TopK(0.5))
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    let mut fresh_params = Vec::new();
    b.net_mut().visit_params(&mut |p, _| fresh_params.push(p.data.clone()));
    let err = b.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("compress"), "unexpected error: {err}");
    assert_eq!(b.iters_done(), 0, "failed restore must not advance the session");
    let mut after = Vec::new();
    b.net_mut().visit_params(&mut |p, _| after.push(p.data.clone()));
    assert_eq!(fresh_params, after, "failed restore must leave parameters untouched");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn parallel_checkpoint_loads_into_host_session() {
    // Deploying a data-parallel run into a single-replica session is
    // legitimate: comm controllers (and any compression residuals) are
    // simply dropped — nothing to communicate — and the model/optimizer
    // state carries over.
    let path = ckpt_path("tohost");
    let mut a = SessionBuilder::classifier("mlp")
        .compress(CompressPolicy::TopK(0.1))
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    a.run(4).unwrap();
    a.save_checkpoint(&path).unwrap();

    let mut b = SessionBuilder::classifier("mlp").build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), 4);
    b.run(3).unwrap(); // and it keeps training
    let _ = std::fs::remove_file(&path);
}

// -------------------------------------------------------------- fixtures

/// The committed fixtures were written against this exact configuration:
/// a single `fc0: Linear(4 → 3)` over a 3-class 1×2×2 synthetic stream
/// (the same network as the host-path fixtures in `test_mem.rs`).
fn fixture_builder(mode: QuantMode) -> SessionBuilder {
    SessionBuilder::custom("fixture-net", move |rng| {
        Sequential::new(vec![Box::new(Linear::new("fc0", 4, 3, mode, rng))])
    })
    .data(Box::new(SynthImages::new(11, 3, 1, 2, 2, 0.3)))
    .eval_set(999, 12)
}

#[test]
fn v3_topk_fixture_checkpoint_loads_with_residuals() {
    let path = fixture("parallel_topk_v3.ckpt");
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.iters_done(), 2);
    let snap = ck.compress_state().expect("fixture carries a compress section");
    assert_eq!(snap.label, "topk:0.25");
    assert_eq!(snap.residuals.len(), 4, "2 tensors × 2 replicas");
    assert_eq!(snap.residuals[0].2.len(), 12, "fc0 weight residual");
    assert_eq!(snap.residuals[3].2.len(), 3, "fc0 bias residual");

    // loads into the matching group and the residual state resumes exactly
    let mut s = fixture_builder(QuantMode::Float32)
        .compress(CompressPolicy::TopK(0.25))
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    s.load_checkpoint(&path).unwrap();
    assert_eq!(s.iters_done(), 2);
    assert_eq!(s.backend().group().comm().compress_snapshot(), *snap);
    s.run(2).unwrap(); // and it keeps training
    assert!(s.losses().iter().all(|l| l.is_finite()));

    // a group under a different compression policy must refuse it
    let mut wrong = fixture_builder(QuantMode::Float32)
        .compress(CompressPolicy::TopK(0.5))
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    let err = wrong.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("compress"), "unexpected error: {err}");
}

#[test]
fn v1_fixture_checkpoint_loads_into_parallel_group() {
    // Pre-compression artifacts keep loading into stateless policies: the
    // missing compress section restores fine into a `none` group…
    let path = fixture("host_f32_v1.ckpt");
    let mut s = fixture_builder(QuantMode::Float32)
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    s.load_checkpoint(&path).unwrap();
    assert_eq!(s.iters_done(), 3);
    s.run(2).unwrap();
    assert!(s.replicas_in_sync());

    // …but an error-feedback group cannot invent residuals it never saved.
    let mut topk = fixture_builder(QuantMode::Float32)
        .compress(CompressPolicy::TopK(0.25))
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    let err = topk.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("compress"), "unexpected error: {err}");
}
