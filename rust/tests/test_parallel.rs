//! Data-parallel training contract tests (DESIGN.md §Data-Parallel):
//!
//! 1. **Single-replica parity** — `build_parallel(1, _)` is bit-identical
//!    to the plain host `Session` loop for every comm policy (nothing is
//!    communicated at N = 1; that is the documented exactness condition).
//! 2. **Tree-reduction oracle** — at N ∈ {2, 4} with f32 comm, the loss
//!    and parameter trajectories match an independently implemented
//!    shard → backward → fixed-order tree reduction → shared SGD ladder
//!    bit-exactly.
//! 3. **Quantized-comm convergence** — int8 gradient exchange still trains
//!    the tier-1 mlp/alexnet configs.
//! 4. **Sync invariant** — replicas hold bit-identical parameters after
//!    any number of steps, under quantized compute and comm.
//! 5. **Checkpoint round-trip** — the per-gradient communication
//!    controllers (and the whole group) resume bit-identically.

use apt::apt::AptConfig;
use apt::data::SynthImages;
use apt::nn::loss::softmax_xent;
use apt::nn::{models, QuantMode, TrainCtx};
use apt::train::{CommPrecision, Optimizer, Sgd, SessionBuilder};
use apt::util::Pcg32;

fn adaptive(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

fn comm_adaptive(iters: u64) -> CommPrecision {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    CommPrecision::Adaptive(cfg)
}

// ---------------------------------------------------------------- parity

fn assert_replicas_one_matches_host(mode: QuantMode, comm: CommPrecision, iters: u64) {
    let mut host = SessionBuilder::classifier("mlp").mode(mode).build();
    host.run(iters).unwrap();
    let mut par = SessionBuilder::classifier("mlp")
        .mode(mode)
        .build_parallel(1, comm)
        .unwrap();
    par.run(iters).unwrap();

    assert_eq!(host.losses(), par.losses(), "loss trajectories diverged at N=1");
    let (ha, pa) = (host.eval().unwrap(), par.eval().unwrap());
    assert_eq!(ha.accuracy, pa.accuracy, "eval diverged at N=1");
    let mut hp = Vec::new();
    let mut pp = Vec::new();
    host.net_mut().visit_params(&mut |p, _| hp.push(p.data.clone()));
    par.net_mut().visit_params(&mut |p, _| pp.push(p.data.clone()));
    assert_eq!(hp, pp, "parameters diverged at N=1");
}

#[test]
fn replicas_one_bit_identical_to_host_loop() {
    // The comm policy must be irrelevant at N = 1 — int8 codes never touch
    // the gradients because there is nothing to exchange.
    let iters = 25;
    assert_replicas_one_matches_host(QuantMode::Float32, CommPrecision::F32, iters);
    assert_replicas_one_matches_host(QuantMode::Float32, CommPrecision::Static(8), iters);
    assert_replicas_one_matches_host(adaptive(iters), CommPrecision::Static(8), iters);
}

// ------------------------------------------------------ tree-reduce oracle

/// Independent re-implementation of the documented reduction ladder:
/// recursive split at the largest power of two strictly below `n`, which
/// is provably the same association as the stride-doubling loop in
/// `train::parallel::tree_reduce_f32`.
fn oracle_tree(parts: &[Vec<f32>]) -> Vec<f32> {
    let n = parts.len();
    if n == 1 {
        return parts[0].clone();
    }
    let mut p = 1usize;
    while p * 2 < n {
        p *= 2;
    }
    let left = oracle_tree(&parts[..p]);
    let right = oracle_tree(&parts[p..]);
    left.iter().zip(&right).map(|(a, b)| a + b).collect()
}

/// The data-parallel step sequence, rebuilt from public primitives only:
/// N identically seeded nets, one shared batch stream, row-sharding,
/// per-replica backward, oracle tree reduction + mean, per-replica SGD.
fn oracle_parallel(
    mode: QuantMode,
    replicas: usize,
    iters: u64,
    lr: f32,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let batch = 16usize;
    let shard = batch / replicas;
    let mut nets: Vec<_> = (0..replicas)
        .map(|_| {
            let mut rng = Pcg32::seeded(0);
            models::by_name("mlp", mode, &mut rng).expect("model")
        })
        .collect();
    let mut ctxs: Vec<TrainCtx> = (0..replicas).map(|_| TrainCtx::new()).collect();
    let mut opts: Vec<Sgd> = (0..replicas).map(|_| Sgd::new(lr, 0.9)).collect();
    let mut data = SynthImages::new(
        1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let mut losses = Vec::new();
    for it in 0..iters {
        let (x, y) = data.batch(batch);
        let d = x.dim(1);
        let mut shard_losses = Vec::new();
        let mut grads: Vec<Vec<Vec<f32>>> = Vec::new();
        for r in 0..replicas {
            ctxs[r].iter = it;
            let xs = apt::tensor::Tensor::from_vec(
                &[shard, d],
                x.data[r * shard * d..(r + 1) * shard * d].to_vec(),
            );
            let ys = &y[r * shard..(r + 1) * shard];
            let logits = nets[r].forward(&xs, &mut ctxs[r]);
            let (l, g) = softmax_xent(&logits, ys);
            nets[r].backward(&g, &mut ctxs[r]);
            shard_losses.push(l);
            let mut gs = Vec::new();
            nets[r].visit_params(&mut |_, gr| gs.push(gr.data.clone()));
            grads.push(gs);
        }
        let tensors = grads[0].len();
        let mut avg: Vec<Vec<f32>> = Vec::with_capacity(tensors);
        for t in 0..tensors {
            let parts: Vec<Vec<f32>> = grads.iter().map(|g| g[t].clone()).collect();
            let mut sum = oracle_tree(&parts);
            let inv = 1.0 / replicas as f32;
            for v in &mut sum {
                *v *= inv;
            }
            avg.push(sum);
        }
        for r in 0..replicas {
            let mut i = 0usize;
            nets[r].visit_params(&mut |_, gr| {
                gr.data.copy_from_slice(&avg[i]);
                i += 1;
            });
            opts[r].step(&mut nets[r]);
            nets[r].zero_grads();
        }
        losses.push(
            (shard_losses.iter().map(|&l| l as f64).sum::<f64>() / replicas as f64) as f32,
        );
    }
    let mut params = Vec::new();
    nets[0].visit_params(&mut |p, _| params.push(p.data.clone()));
    (losses, params)
}

fn assert_f32_comm_matches_oracle(mode: QuantMode, replicas: usize, iters: u64) {
    let lr = 0.02;
    let (oracle_losses, oracle_params) = oracle_parallel(mode, replicas, iters, lr);
    let mut s = SessionBuilder::classifier("mlp")
        .mode(mode)
        .lr(lr)
        .build_parallel(replicas, CommPrecision::F32)
        .unwrap();
    s.run(iters).unwrap();
    assert_eq!(
        s.losses(),
        &oracle_losses[..],
        "N={replicas}: loss curve diverged from the tree-reduction oracle"
    );
    let mut params = Vec::new();
    s.net_mut().visit_params(&mut |p, _| params.push(p.data.clone()));
    assert_eq!(params.len(), oracle_params.len());
    for (i, (a, b)) in params.iter().zip(&oracle_params).enumerate() {
        assert_eq!(a, b, "N={replicas}: parameter {i} diverged from the oracle");
    }
}

#[test]
fn f32_comm_matches_tree_oracle_two_replicas() {
    assert_f32_comm_matches_oracle(QuantMode::Float32, 2, 15);
}

#[test]
fn f32_comm_matches_tree_oracle_four_replicas() {
    assert_f32_comm_matches_oracle(QuantMode::Float32, 4, 15);
}

#[test]
fn f32_comm_matches_tree_oracle_quantized_compute() {
    // Quantized *compute* (per-replica QEM/QPA inside the layers) with f32
    // *comm* still matches the oracle: the controllers are deterministic
    // functions of each replica's shard.
    assert_f32_comm_matches_oracle(QuantMode::Static(8), 2, 12);
}

// ------------------------------------------------------------ convergence

#[test]
fn int8_comm_converges_mlp() {
    let iters = 60;
    let rec = {
        let mut s = SessionBuilder::classifier("mlp")
            .mode(adaptive(iters))
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap();
        s.run(iters).unwrap();
        s.record().unwrap()
    };
    let first: f64 = rec.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(
        rec.tail_loss(10) < first * 0.8,
        "int8 comm failed to train mlp: first {first:.4} tail {:.4}",
        rec.tail_loss(10)
    );
    assert!(rec.eval_acc > 0.15, "acc={}", rec.eval_acc); // better than chance
    // the communication controllers actually ran at int8
    assert!(!rec.grad_bits.is_empty());
    assert!(rec.grad_bits.iter().all(|(n, b)| n.starts_with("comm:") && *b == 8));
}

#[test]
fn int8_comm_converges_alexnet() {
    let iters = 25;
    let rec = {
        let mut s = SessionBuilder::classifier("alexnet")
            .mode(adaptive(iters))
            .lr(0.01)
            .build_parallel(2, CommPrecision::Static(8))
            .unwrap();
        s.run(iters).unwrap();
        s.record().unwrap()
    };
    let first: f64 = rec.losses[..5].iter().map(|&x| x as f64).sum::<f64>() / 5.0;
    assert!(
        rec.tail_loss(5) < first,
        "int8 comm failed to reduce alexnet loss: first {first:.4} tail {:.4}",
        rec.tail_loss(5)
    );
}

// ----------------------------------------------------------- sync + misc

#[test]
fn replicas_stay_in_sync_under_quantized_comm() {
    let iters = 12;
    let mut s = SessionBuilder::classifier("mlp")
        .mode(adaptive(iters))
        .build_parallel(4, comm_adaptive(iters))
        .unwrap();
    s.run(iters).unwrap();
    assert!(s.replicas_in_sync(), "peer parameters diverged from the root replica");
    assert_eq!(s.replicas(), 4);
}

#[test]
fn batch_must_split_evenly() {
    let err = SessionBuilder::classifier("mlp")
        .batch(10)
        .build_parallel(3, CommPrecision::F32)
        .err()
        .expect("10 across 3 replicas must be rejected");
    assert!(err.to_string().contains("split"), "unexpected error: {err}");
}

// ------------------------------------------------------------ checkpoints

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_par_ckpt_{tag}_{}.txt", std::process::id()))
}

#[test]
fn parallel_checkpoint_roundtrip_is_bit_identical() {
    // f32 compute + adaptive int comm: every piece of state that matters —
    // params, optimizer, data RNG, and the communication controllers — is
    // in the checkpoint, so the restored run must continue bit-identically.
    let (pre, post) = (8u64, 8u64);
    let iters = pre + post;
    let build = || {
        SessionBuilder::classifier("mlp")
            .build_parallel(2, comm_adaptive(iters))
            .unwrap()
    };
    let path = ckpt_path("comm");

    let mut a = build();
    a.run(pre).unwrap();
    a.save_checkpoint(&path).unwrap();
    a.run(post).unwrap();

    let mut b = build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), pre);
    b.run(post).unwrap();

    assert_eq!(a.losses(), b.losses(), "restored run diverged");
    assert!(b.replicas_in_sync());
    let mut pa = Vec::new();
    let mut pb = Vec::new();
    a.net_mut().visit_params(&mut |p, _| pa.push(p.data.clone()));
    b.net_mut().visit_params(&mut |p, _| pb.push(p.data.clone()));
    assert_eq!(pa, pb, "parameters diverged after restore");

    // the communication controllers themselves round-tripped exactly
    let sa = a.backend().group().comm().snapshot();
    let sb = b.backend().group().comm().snapshot();
    assert_eq!(sa, sb, "communication controller state diverged");
    assert!(!sa.is_empty(), "adaptive comm must have controllers");

    let _ = std::fs::remove_file(&path);
}

#[test]
fn parallel_checkpoint_rejects_comm_policy_mismatch() {
    let path = ckpt_path("policy");
    let mut a = SessionBuilder::classifier("mlp")
        .build_parallel(2, CommPrecision::Static(8))
        .unwrap();
    a.run(3).unwrap();
    a.save_checkpoint(&path).unwrap();

    // f32-comm group has no controllers → restore must fail loudly,
    // and must fail *before* mutating anything (validate-then-apply).
    let mut b = SessionBuilder::classifier("mlp")
        .build_parallel(2, CommPrecision::F32)
        .unwrap();
    let mut fresh_params = Vec::new();
    b.net_mut().visit_params(&mut |p, _| fresh_params.push(p.data.clone()));
    let err = b.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("communication"), "unexpected error: {err}");
    assert_eq!(b.iters_done(), 0, "failed restore must not advance the session");
    let mut after = Vec::new();
    b.net_mut().visit_params(&mut |p, _| after.push(p.data.clone()));
    assert_eq!(fresh_params, after, "failed restore must leave parameters untouched");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn parallel_checkpoint_loads_into_host_session() {
    // Deploying a data-parallel run into a single-replica session is
    // legitimate: comm controllers are simply dropped (nothing to
    // communicate), and the model/optimizer state carries over.
    let path = ckpt_path("tohost");
    let mut a = SessionBuilder::classifier("mlp")
        .build_parallel(2, CommPrecision::Static(8))
        .unwrap();
    a.run(4).unwrap();
    a.save_checkpoint(&path).unwrap();

    let mut b = SessionBuilder::classifier("mlp").build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), 4);
    b.run(3).unwrap(); // and it keeps training
    let _ = std::fs::remove_file(&path);
}
