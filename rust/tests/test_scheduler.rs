//! Scheduler conformance suite (DESIGN.md §Serving-Tier): every
//! [`Scheduler`] implementation runs one shared property battery over
//! random arrival/advance/dispatch/deadline sequences (mirroring
//! `test_qpa_props.rs`'s use of the in-tree proptest harness):
//!
//! 1. **No request lost or duplicated** — every admitted id resolves to
//!    exactly one of dispatched / expired / evicted / drained.
//! 2. **Batch size ≤ `max_batch`** on every dispatch.
//! 3. **FIFO within a priority lane** — dispatch order preserves
//!    admission order lane-by-lane.
//! 4. **Shedding is explicit** — refusals happen only under declared
//!    conditions (full queue, infeasible deadline) with a reason; the
//!    queue is bounded by `queue_cap` at all times.
//!
//! Plus policy-specific behaviour pins (flush hold timer, continuous
//! work-conservation, priority eviction) and the loadgen determinism
//! contract: same seed ⇒ byte-identical arrival trace ⇒ identical
//! virtual-time `serve_slo.csv` row on 1 worker.

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use apt::bench::loadgen::{self, SimCost, Trace};
use apt::serve::{
    Admit, Plan, SchedConfig, SchedCtx, SchedEntry, SchedPolicy, Scheduler, ShedReason,
};
use apt::util::proptest::{check, Gen};

const POLICIES: [SchedPolicy; 2] = [SchedPolicy::Flush, SchedPolicy::Continuous];

/// Drives one scheduler through a synthetic event sequence with a
/// virtual clock, recording the fate of every admitted id.
struct Harness {
    base: Instant,
    cfg: SchedConfig,
    sched: Box<dyn Scheduler>,
    now_us: u64,
    next_id: u64,
    est_req_secs: f64,
    workers: usize,
    /// ids currently queued (mirror of the scheduler's claimed content).
    queued: HashSet<u64>,
    lane_of: HashMap<u64, usize>,
    /// flattened dispatch order across all batches.
    dispatched: Vec<u64>,
    expired: Vec<u64>,
    evicted: Vec<u64>,
    refused: Vec<(u64, ShedReason)>,
    max_batch_seen: usize,
}

impl Harness {
    fn new(policy: SchedPolicy, cfg: SchedConfig, est_req_secs: f64) -> Harness {
        Harness {
            base: Instant::now(),
            cfg,
            sched: policy.build(cfg),
            now_us: 0,
            next_id: 0,
            est_req_secs,
            workers: 1,
            queued: HashSet::new(),
            lane_of: HashMap::new(),
            dispatched: Vec::new(),
            expired: Vec::new(),
            evicted: Vec::new(),
            refused: Vec::new(),
            max_batch_seen: 0,
        }
    }

    fn at(&self, us: u64) -> Instant {
        self.base + Duration::from_micros(us)
    }

    fn ctx(&self) -> SchedCtx {
        SchedCtx { now: self.at(self.now_us), est_req_secs: self.est_req_secs, workers: self.workers }
    }

    fn arrive(&mut self, lane: usize, deadline_us: Option<u64>) -> Admit {
        let id = self.next_id;
        self.next_id += 1;
        let len_before = self.sched.len();
        let e = SchedEntry {
            id,
            lane,
            deadline: deadline_us.map(|d| self.at(self.now_us + d)),
            arrived: self.at(self.now_us),
        };
        let outcome = self.sched.admit(e, &self.ctx());
        match outcome {
            Admit::Queued => {
                assert!(
                    len_before < self.cfg.queue_cap,
                    "admitted past queue_cap ({} queued)",
                    len_before
                );
                self.queued.insert(id);
                self.lane_of.insert(id, lane.min(self.cfg.lanes - 1));
            }
            Admit::Evict { victim } => {
                assert!(len_before >= self.cfg.queue_cap, "evicted below capacity");
                assert!(self.queued.remove(&victim), "evicted id {victim} was not queued");
                let (vl, nl) = (self.lane_of[&victim], lane.min(self.cfg.lanes - 1));
                assert!(vl > nl, "evicted lane {vl} is not lower priority than arrival lane {nl}");
                self.evicted.push(victim);
                self.queued.insert(id);
                self.lane_of.insert(id, nl);
            }
            Admit::Shed(reason) => {
                match reason {
                    ShedReason::QueueFull => assert!(
                        len_before >= self.cfg.queue_cap,
                        "QueueFull shed with only {len_before} queued"
                    ),
                    ShedReason::DeadlineUnmeetable => assert!(
                        deadline_us.is_some(),
                        "DeadlineUnmeetable shed for a request with no deadline"
                    ),
                    other => panic!("admission shed with non-admission reason {other:?}"),
                }
                self.refused.push((id, reason));
            }
        }
        assert_eq!(self.sched.len(), self.queued.len(), "scheduler len drifted from mirror");
        outcome
    }

    /// One idle worker asks for work.
    fn plan(&mut self) -> Plan {
        let plan = self.sched.plan(&self.ctx());
        match &plan {
            Plan::Dispatch { batch, expired } => {
                assert!(
                    batch.len() <= self.cfg.max_batch,
                    "batch of {} exceeds max_batch {}",
                    batch.len(),
                    self.cfg.max_batch
                );
                self.max_batch_seen = self.max_batch_seen.max(batch.len());
                for id in batch {
                    assert!(self.queued.remove(id), "dispatched id {id} was not queued");
                    self.dispatched.push(*id);
                }
                for id in expired {
                    assert!(self.queued.remove(id), "expired id {id} was not queued");
                    self.expired.push(*id);
                }
            }
            Plan::Wait(hold) => {
                if self.sched.is_empty() {
                    assert_eq!(*hold, None, "empty queue must wait for an arrival, not a timer");
                }
            }
        }
        assert_eq!(self.sched.len(), self.queued.len(), "scheduler len drifted from mirror");
        plan
    }

    fn advance(&mut self, us: u64) {
        self.now_us += us;
    }

    /// Shutdown: everything still queued must come back exactly once.
    fn drain_and_verify(mut self) {
        let drained = self.sched.drain();
        assert_eq!(self.sched.len(), 0);
        let drained_set: HashSet<u64> = drained.iter().copied().collect();
        assert_eq!(drained.len(), drained_set.len(), "drain returned duplicates");
        assert_eq!(drained_set, self.queued, "drain lost or invented ids");

        // Global conservation: every admitted id has exactly one fate.
        let mut fates: HashMap<u64, usize> = HashMap::new();
        for id in self
            .dispatched
            .iter()
            .chain(self.expired.iter())
            .chain(self.evicted.iter())
            .chain(drained.iter())
        {
            *fates.entry(*id).or_insert(0) += 1;
        }
        for (id, n) in &fates {
            assert_eq!(*n, 1, "id {id} resolved {n} times");
        }
        let admitted = self.next_id as usize - self.refused.len();
        assert_eq!(fates.len(), admitted, "some admitted id was lost");

        // FIFO within each priority lane over the dispatch sequence.
        let mut last_in_lane: HashMap<usize, u64> = HashMap::new();
        for id in &self.dispatched {
            let lane = self.lane_of[id];
            if let Some(prev) = last_in_lane.insert(lane, *id) {
                assert!(
                    prev < *id,
                    "lane {lane} dispatched id {id} after younger id {prev} (FIFO violated)"
                );
            }
        }
    }
}

fn small_cfg(g: &mut Gen) -> SchedConfig {
    SchedConfig {
        max_batch: g.usize(1, 8),
        queue_cap: g.usize(1, 12),
        lanes: g.usize(1, 4),
        max_wait_us: g.usize(0, 500) as u64,
    }
}

#[test]
fn prop_conformance_battery_all_policies() {
    for policy in POLICIES {
        check(&format!("conformance-{}", policy.label()), 150, |g| {
            let cfg = small_cfg(g);
            // Half the cases have a live service estimate so the
            // deadline-feasibility shed path is exercised too.
            let est = if g.int(0, 1) == 0 { 0.0 } else { g.f32_log(1e-6, 1e-3) as f64 };
            let mut h = Harness::new(policy, cfg, est);
            for _ in 0..g.usize(10, 120) {
                match g.int(0, 9) {
                    0..=4 => {
                        let lane = g.usize(0, cfg.lanes + 1); // may exceed lanes-1: clamp path
                        let deadline = if g.int(0, 2) == 0 {
                            Some(g.usize(1, 5_000) as u64)
                        } else {
                            None
                        };
                        h.arrive(lane, deadline);
                    }
                    5..=6 => {
                        h.advance(g.usize(1, 1_000) as u64);
                    }
                    _ => {
                        let _ = h.plan();
                    }
                }
            }
            h.drain_and_verify();
        });
    }
}

#[test]
fn prop_every_dispatch_respects_lane_order() {
    // Within one batch, a lower lane (more urgent) id never follows a
    // higher lane id — batches are formed lane 0 outward.
    for policy in POLICIES {
        check(&format!("lane-order-{}", policy.label()), 80, |g| {
            let cfg = SchedConfig {
                max_batch: g.usize(2, 8),
                queue_cap: 16,
                lanes: 3,
                max_wait_us: 0, // flush dispatches on first plan
            };
            let mut h = Harness::new(policy, cfg, 0.0);
            for _ in 0..g.usize(2, 12) {
                h.arrive(g.usize(0, 2), None);
            }
            h.advance(1);
            if let Plan::Dispatch { batch, .. } = h.plan() {
                let lanes: Vec<usize> = batch.iter().map(|id| h.lane_of[id]).collect();
                let mut sorted = lanes.clone();
                sorted.sort_unstable();
                assert_eq!(lanes, sorted, "batch not in lane-priority order: {lanes:?}");
            } else {
                panic!("non-empty queue with max_wait 0 must dispatch");
            }
            h.drain_and_verify();
        });
    }
}

#[test]
fn flush_holds_partial_batch_until_deadline() {
    let cfg = SchedConfig { max_batch: 8, queue_cap: 64, lanes: 1, max_wait_us: 1_000 };
    let mut h = Harness::new(SchedPolicy::Flush, cfg, 0.0);
    h.arrive(0, None);
    h.arrive(0, None);
    // Before the hold expires: a partial batch is held open.
    match h.plan() {
        Plan::Wait(Some(_)) => {}
        other => panic!("flush should hold a 2/8 batch open, got {other:?}"),
    }
    // After the hold expires: the partial batch flushes.
    h.advance(1_001);
    match h.plan() {
        Plan::Dispatch { batch, .. } => assert_eq!(batch.len(), 2),
        other => panic!("flush should dispatch after max_wait, got {other:?}"),
    }
    h.drain_and_verify();
}

#[test]
fn flush_dispatches_immediately_at_fill_target() {
    let cfg = SchedConfig { max_batch: 4, queue_cap: 64, lanes: 1, max_wait_us: 1_000_000 };
    let mut h = Harness::new(SchedPolicy::Flush, cfg, 0.0);
    for _ in 0..4 {
        h.arrive(0, None);
    }
    match h.plan() {
        Plan::Dispatch { batch, .. } => assert_eq!(batch.len(), 4),
        other => panic!("full batch must not wait out the hold timer, got {other:?}"),
    }
    h.drain_and_verify();
}

#[test]
fn flush_fill_target_clamped_by_queue_cap() {
    // queue_cap < max_batch: a full queue must flush, not hold.
    let cfg = SchedConfig { max_batch: 8, queue_cap: 2, lanes: 1, max_wait_us: 1_000_000 };
    let mut h = Harness::new(SchedPolicy::Flush, cfg, 0.0);
    h.arrive(0, None);
    h.arrive(0, None);
    match h.plan() {
        Plan::Dispatch { batch, .. } => assert_eq!(batch.len(), 2),
        other => panic!("cap-limited queue must flush when full, got {other:?}"),
    }
    h.drain_and_verify();
}

#[test]
fn continuous_never_holds_a_batch() {
    let cfg = SchedConfig { max_batch: 8, queue_cap: 64, lanes: 1, max_wait_us: 1_000_000 };
    let mut h = Harness::new(SchedPolicy::Continuous, cfg, 0.0);
    h.arrive(0, None);
    match h.plan() {
        Plan::Dispatch { batch, .. } => assert_eq!(batch.len(), 1),
        other => panic!("continuous batching must dispatch immediately, got {other:?}"),
    }
    h.drain_and_verify();
}

#[test]
fn admission_evicts_lowest_priority_youngest_first() {
    for policy in POLICIES {
        let cfg = SchedConfig { max_batch: 4, queue_cap: 3, lanes: 3, max_wait_us: 0 };
        let mut h = Harness::new(policy, cfg, 0.0);
        h.arrive(2, None); // id 0, low priority, oldest
        h.arrive(2, None); // id 1, low priority, youngest
        h.arrive(0, None); // id 2, urgent
        // Queue full. An urgent arrival displaces the *youngest* low-
        // priority entry (id 1), keeping lane FIFO for the survivors.
        match h.arrive(0, None) {
            Admit::Evict { victim } => assert_eq!(victim, 1),
            other => panic!("expected eviction, got {other:?}"),
        }
        // A low-priority arrival cannot displace anyone (no lane below it).
        match h.arrive(2, None) {
            Admit::Shed(ShedReason::QueueFull) => {}
            other => panic!("expected QueueFull shed, got {other:?}"),
        }
        h.drain_and_verify();
    }
}

#[test]
fn infeasible_deadline_is_rejected_on_admission() {
    for policy in POLICIES {
        let cfg = SchedConfig { max_batch: 4, queue_cap: 64, lanes: 1, max_wait_us: 0 };
        // 1 ms per request estimated, 10 requests queued ahead ⇒ ~10 ms
        // predicted delay; a 2 ms deadline is unmeetable.
        let mut h = Harness::new(policy, cfg, 1e-3);
        for _ in 0..10 {
            h.arrive(0, None);
        }
        match h.arrive(0, Some(2_000)) {
            Admit::Shed(ShedReason::DeadlineUnmeetable) => {}
            other => panic!("expected reject-on-admission, got {other:?}"),
        }
        // A generous deadline is admitted under the same backlog.
        match h.arrive(0, Some(60_000_000)) {
            Admit::Queued => {}
            other => panic!("expected admission, got {other:?}"),
        }
        h.drain_and_verify();
    }
}

#[test]
fn queued_requests_past_deadline_expire_at_dispatch() {
    for policy in POLICIES {
        let cfg = SchedConfig { max_batch: 4, queue_cap: 64, lanes: 1, max_wait_us: 0 };
        let mut h = Harness::new(policy, cfg, 0.0);
        h.arrive(0, Some(500)); // will expire
        h.arrive(0, None); // no deadline: must run
        h.advance(1_000);
        match h.plan() {
            Plan::Dispatch { batch, expired } => {
                assert_eq!(expired, vec![0], "stale request must expire, not run");
                assert_eq!(batch, vec![1]);
            }
            other => panic!("expected dispatch, got {other:?}"),
        }
        h.drain_and_verify();
    }
}

// ---- loadgen determinism (EXPERIMENTS.md §Serve-SLO) ----

#[test]
fn loadgen_trace_is_deterministic_by_seed() {
    let a = Trace::poisson(7, 2_000, 512, 3);
    let b = Trace::poisson(7, 2_000, 512, 3);
    assert_eq!(a, b, "same seed must give a byte-identical trace");
    assert_eq!(a.fnv(), b.fnv());
    let c = Trace::poisson(8, 2_000, 512, 3);
    assert_ne!(a.arrivals_us, c.arrivals_us, "different seeds must differ");
    // Arrivals are non-decreasing and the mean rate is in the right
    // ballpark (±30% over 512 draws).
    assert!(a.arrivals_us.windows(2).all(|w| w[0] <= w[1]));
    let span_s = *a.arrivals_us.last().unwrap() as f64 * 1e-6;
    let rate = a.len() as f64 / span_s;
    assert!((rate / 2_000.0 - 1.0).abs() < 0.3, "offered rate off: {rate}");
}

#[test]
fn loadgen_sim_row_is_deterministic_on_one_worker() {
    // The determinism pin for results/serve_slo.csv: same seed ⇒ same
    // trace ⇒ identical simulated CSV row, bit for bit, on 1 worker.
    let cost = SimCost { batch_overhead_us: 150, per_row_us: 40 };
    for policy in POLICIES {
        let cfg = SchedConfig { max_batch: 8, queue_cap: 64, lanes: 3, max_wait_us: 200 };
        let run = || {
            let trace = Trace::poisson(42, 3_000, 800, 3);
            let r = loadgen::simulate(policy, cfg, 1, Some(5_000), &trace, cost);
            loadgen::slo_csv_row("sim", policy, &trace, 1, cfg.max_batch, Some(5_000), &r)
        };
        assert_eq!(run(), run(), "{} sim row must be reproducible", policy.label());
    }
}

#[test]
fn loadgen_sim_accounts_every_request() {
    let cost = SimCost { batch_overhead_us: 100, per_row_us: 50 };
    for policy in POLICIES {
        for qps in [500u64, 5_000, 50_000] {
            let cfg = SchedConfig { max_batch: 8, queue_cap: 32, lanes: 3, max_wait_us: 200 };
            let trace = Trace::poisson(3, qps, 600, 3);
            let r = loadgen::simulate(policy, cfg, 2, Some(4_000), &trace, cost);
            assert!(
                r.accounted(),
                "{} @ {qps} qps: {} submitted ≠ {} served + {} shed + {} refused",
                policy.label(),
                r.submitted,
                r.served,
                r.shed,
                r.shed_admission
            );
            // Single lane + no deadline: eviction and expiry are both
            // impossible, so nothing admitted is ever shed later.
            let cfg1 = SchedConfig { lanes: 1, ..cfg };
            let trace1 = Trace::poisson(3, qps, 600, 1);
            let r2 = loadgen::simulate(policy, cfg1, 2, None, &trace1, cost);
            assert!(r2.accounted());
            assert_eq!(r2.shed, 0, "single lane + no deadline ⇒ nothing shed post-admission");
        }
    }
}

#[test]
fn sim_continuous_beats_flush_p99_at_light_load() {
    // The SLO claim in deterministic virtual time: with a 2 ms hold
    // timer and arrivals slower than the service rate, flush-and-wait
    // pays the hold on most batches; continuous batching dispatches on
    // arrival. (The wall-clock version of this table is
    // results/serve_slo.csv from bench_serve_slo.)
    let cfg = SchedConfig { max_batch: 16, queue_cap: 256, lanes: 3, max_wait_us: 2_000 };
    let cost = SimCost { batch_overhead_us: 100, per_row_us: 50 };
    let trace = Trace::poisson(11, 1_000, 1_000, 3);
    let flush = loadgen::simulate(SchedPolicy::Flush, cfg, 2, None, &trace, cost);
    let cont = loadgen::simulate(SchedPolicy::Continuous, cfg, 2, None, &trace, cost);
    assert_eq!(flush.served, trace.len() as u64);
    assert_eq!(cont.served, trace.len() as u64);
    assert!(
        cont.p99_us < flush.p99_us,
        "continuous p99 {:.0}µs should beat flush p99 {:.0}µs at 1k QPS",
        cont.p99_us,
        flush.p99_us
    );
}
