//! Integration over the pure-Rust path: Algorithm 1's accuracy claims on
//! the mini model zoo, plus ledger/controller invariants over a real run —
//! all driven through the unified `train::Session` API.

use apt::apt::AptConfig;
use apt::fixedpoint::TensorKind;
use apt::nn::QuantMode;
use apt::train::SessionBuilder;

fn adaptive(iters: u64) -> QuantMode {
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    QuantMode::Adaptive(cfg)
}

#[test]
fn adaptive_matches_float32_on_alexnet_mini() {
    let iters = 250;
    let f = SessionBuilder::classifier("alexnet").lr(0.01).train(iters);
    let q = SessionBuilder::classifier("alexnet")
        .lr(0.01)
        .mode(adaptive(iters))
        .train(iters);
    assert!(f.eval_acc > 0.5, "f32 baseline too weak: {}", f.eval_acc);
    assert!(
        q.eval_acc > f.eval_acc - 0.08,
        "adaptive dropped too much: {} vs {}",
        q.eval_acc,
        f.eval_acc
    );
}

#[test]
fn unified_int8_is_no_better_than_adaptive() {
    let iters = 250;
    let q = SessionBuilder::classifier("alexnet")
        .lr(0.01)
        .mode(adaptive(iters))
        .train(iters);
    let i8 = SessionBuilder::classifier("alexnet")
        .lr(0.01)
        .mode(QuantMode::Static(8))
        .train(iters);
    assert!(
        i8.eval_acc <= q.eval_acc + 0.05,
        "int8-unified {} should not beat adaptive {}",
        i8.eval_acc,
        q.eval_acc
    );
}

#[test]
fn ledger_invariants_over_real_run() {
    let iters = 200;
    let run = SessionBuilder::classifier("alexnet").mode(adaptive(iters)).train(iters);
    let l = &run.ledger;
    // every gradient tensor recorded at least one event, first at iter 0
    for ((name, kind), hist) in &l.tensors {
        if *kind != TensorKind::Gradient {
            continue;
        }
        assert!(!hist.events.is_empty(), "{name}: no events");
        assert_eq!(hist.events[0].iter, 0, "{name}: first update not at iter 0");
        // events strictly increasing in iteration
        for w in hist.events.windows(2) {
            assert!(w[1].iter > w[0].iter, "{name}: non-monotone events");
        }
        // Mode2: bits never decrease
        for w in hist.events.windows(2) {
            assert!(w[1].bits >= w[0].bits, "{name}: Mode2 bits decreased");
        }
        // intervals grow overall: last interval >= first
        let first_itv = hist.events.first().unwrap().interval;
        let last_itv = hist.events.last().unwrap().interval;
        assert!(last_itv >= first_itv, "{name}: interval shrank {first_itv}→{last_itv}");
    }
    // mix percentages sum to ~1
    let mix = l.timewise_bits_mix(TensorKind::Gradient);
    let total: f64 = mix.values().sum();
    assert!((total - 1.0).abs() < 1e-6, "mix sums to {total}");
}

#[test]
fn weights_and_activations_stay_int8() {
    let iters = 120;
    let run = SessionBuilder::classifier("alexnet").mode(adaptive(iters)).train(iters);
    for ((name, kind), hist) in &run.ledger.tensors {
        if *kind == TensorKind::Gradient {
            continue;
        }
        for ev in &hist.events {
            assert_eq!(ev.bits, 8, "{name} {kind:?} escalated to {}", ev.bits);
        }
    }
}

#[test]
fn mode1_allows_bit_decrease_mode2_does_not() {
    let iters = 200;
    let mut cfg1 = AptConfig::mode1();
    cfg1.init_phase_iters = iters / 10;
    let run1 = SessionBuilder::classifier("alexnet")
        .mode(QuantMode::Adaptive(cfg1))
        .train(iters);
    // Mode1 events may decrease bits; just verify the run is healthy and
    // that bit values stay in the legal set.
    for ((_, kind), hist) in &run1.ledger.tensors {
        if *kind != TensorKind::Gradient {
            continue;
        }
        for ev in &hist.events {
            assert!([8, 16, 24, 32].contains(&ev.bits));
        }
    }
    assert!(run1.eval_acc > 0.3, "mode1 run unhealthy: {}", run1.eval_acc);
}

#[test]
fn failure_injection_exploding_gradients_escalate_bits() {
    // Feed a controller an adversarial stream: benign → exploding-range
    // long-tail gradients. The controller must escalate rather than stay
    // at int8, and the range EMA must follow.
    use apt::apt::{Ledger, PrecisionController};
    use apt::util::Pcg32;
    let mut cfg = AptConfig::default();
    cfg.init_phase_iters = 0;
    let mut c = PrecisionController::new(cfg, "inject", TensorKind::Gradient);
    let mut ledger = Ledger::new();
    let mut rng = Pcg32::seeded(0);
    let benign: Vec<f32> = (0..4096).map(|_| rng.normal()).collect();
    c.maybe_update_from_data(0, &benign, &mut ledger);
    assert_eq!(c.bits(), 8);
    // explode: a few huge spikes blow up the range so the int8 grid
    // swallows the (sum-dominating) small-magnitude mass — the case where
    // the mean-change metric M1 must trip. (Spike-dominated sums do NOT
    // trip M1 by design: the spikes are representable.)
    let tail: Vec<f32> = (0..100_000)
        .map(|i| if i < 4 { 1e4 } else { rng.normal() })
        .collect();
    let mut it = 1;
    while !c.needs_update(it) {
        it += 1;
    }
    c.maybe_update_from_data(it, &tail, &mut ledger);
    assert!(c.bits() >= 16, "controller failed to escalate: {}", c.bits());
}
