//! Property tests for the QPA decision rules (`apt::qpa`), via the
//! in-tree `util::proptest` harness: bit-width choice under Mode1/Mode2
//! and both threshold interpretations, and the interval rule with its
//! `max_interval` clamp (the fully-converged-tensor guard).

use apt::apt::qpa::{choose_bits, error_for_threshold, interval_with_clamp};
use apt::apt::{AptConfig, Mode, ThresholdOn};
use apt::util::proptest::check;

/// A random monotone non-increasing error table over the QPA widths —
/// more bits never probe worse (the shape real QEM errors have).
fn error_table(g: &mut apt::util::proptest::Gen) -> [f64; 4] {
    let e8 = g.f32_log(1e-6, 1.0) as f64;
    let e16 = e8 * g.f32(0.0, 1.0) as f64;
    let e24 = e16 * g.f32(0.0, 1.0) as f64;
    let e32 = e24 * g.f32(0.0, 1.0) as f64;
    [e8, e16, e24, e32]
}

fn probe_of(table: [f64; 4]) -> impl Fn(u8) -> f64 {
    move |bits| match bits {
        0..=8 => table[0],
        9..=16 => table[1],
        17..=24 => table[2],
        _ => table[3],
    }
}

#[test]
fn prop_choose_bits_bounds_and_threshold() {
    check("choose-bits-bounds", 200, |g| {
        let mut cfg = AptConfig::default();
        cfg.mode = *g.choose(&[Mode::Mode1, Mode::Mode2]);
        cfg.threshold = g.f32_log(1e-4, 0.5) as f64;
        let table = error_table(g);
        let probe = probe_of(table);
        let current = *g.choose(&[8u8, 16, 24, 32]);
        let (bits, err) = choose_bits(&cfg, current, &probe);
        assert!(bits >= cfg.min_bits && bits <= cfg.max_bits, "bits={bits}");
        // Either the chosen width meets the threshold, or growth is capped.
        assert!(
            err <= cfg.threshold || bits == cfg.max_bits,
            "bits={bits} err={err} T={}",
            cfg.threshold
        );
        // Mode2 never shrinks below the current width; Mode1 may.
        if cfg.mode == Mode::Mode2 {
            assert!(bits >= current.min(cfg.max_bits), "mode2 shrank: {bits} < {current}");
        }
    });
}

#[test]
fn prop_mode1_is_history_free() {
    check("mode1-history-free", 100, |g| {
        let mut cfg = AptConfig::mode1();
        cfg.threshold = g.f32_log(1e-4, 0.5) as f64;
        let table = error_table(g);
        let probe = probe_of(table);
        let (from8, _) = choose_bits(&cfg, 8, &probe);
        let (from32, _) = choose_bits(&cfg, 32, &probe);
        assert_eq!(from8, from32, "Mode1 must restart the search identically");
    });
}

#[test]
fn prop_threshold_on_diff_and_ratio_agree() {
    // T compared against the ratio, and log2(T+1) compared against
    // Diff = log2(ratio+1), accept exactly the same widths (log2 is
    // monotone). The two configs must always choose the same bits.
    check("diff-ratio-agree", 150, |g| {
        let mut cfg_r = AptConfig::default();
        cfg_r.threshold_on = ThresholdOn::Ratio;
        cfg_r.threshold = g.f32_log(1e-4, 0.5) as f64;
        let mut cfg_d = cfg_r;
        cfg_d.threshold_on = ThresholdOn::Diff;
        cfg_d.threshold = (cfg_r.threshold + 1.0).log2();

        let table = error_table(g);
        let probe_ratio = probe_of(table);
        // the Diff-space probe reports log2(ratio+1), as QEM does
        let probe_diff = |bits: u8| error_for_threshold(&cfg_d, probe_ratio(bits));

        let current = *g.choose(&[8u8, 16]);
        let (br, _) = choose_bits(&cfg_r, current, &probe_ratio);
        let (bd, _) = choose_bits(&cfg_d, current, &probe_diff);
        assert_eq!(br, bd, "threshold spaces disagreed");
    });
}

#[test]
fn prop_interval_bounds_and_clamp() {
    check("interval-bounds", 300, |g| {
        let mut cfg = AptConfig::default();
        cfg.max_interval = g.usize(1, 1_000_000) as u64;
        let diff = g.f32_log(1e-12, 10.0) as f64 * g.int(0, 1) as f64;
        let range_delta = g.f32(-2.0, 2.0) * g.int(0, 1) as f32;
        let in_init = g.int(0, 1) == 1;
        let (itv, clamped) = interval_with_clamp(&cfg, diff, range_delta, in_init);
        assert!(itv >= 1, "interval must be ≥ 1");
        assert!(itv <= cfg.max_interval.max(1), "interval {itv} above ceiling");
        if in_init {
            assert_eq!((itv, clamped), (1, false), "init phase pins Itv = 1");
        }
        if clamped {
            assert_eq!(itv, cfg.max_interval, "clamp must land exactly on the ceiling");
        }
    });
}

#[test]
fn prop_interval_monotone_in_stability() {
    // A more stable tensor (smaller Diff, smaller |ΔR|) never re-probes
    // sooner than a less stable one.
    check("interval-monotone", 200, |g| {
        let cfg = AptConfig::default();
        let d1 = g.f32_log(1e-6, 1.0) as f64;
        let d2 = d1 * g.f32(0.0, 1.0) as f64;
        let r1 = g.f32_log(1e-6, 1.0);
        let r2 = r1 * g.f32(0.0, 1.0);
        let (i1, _) = interval_with_clamp(&cfg, d1, r1, false);
        let (i2, _) = interval_with_clamp(&cfg, d2, r2, false);
        assert!(i2 >= i1, "stability decreased the interval: {i2} < {i1}");
    });
}
