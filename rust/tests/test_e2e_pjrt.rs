//! Integration: the full three-layer stack — Rust coordinator driving the
//! AOT MLP train-step artifact with live QEM/QPA, loss must decrease.
//! Skips when artifacts are absent.

use apt::coordinator::{mlp_slot_names, ArtifactTrainer};
use apt::nn::QuantMode;
use apt::runtime::{HostValue, Runtime};
use apt::util::Pcg32;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

/// Class-template batch matching the artifact's 64-dim input, 10 classes.
fn batch(rng: &mut Pcg32, templates: &[f32]) -> (HostValue, HostValue) {
    let mut x = vec![0.0f32; 32 * 64];
    let mut y = vec![0i32; 32];
    for b in 0..32 {
        let cls = rng.below(10);
        y[b] = cls as i32;
        for j in 0..64 {
            x[b * 64 + j] = templates[cls * 64 + j] + rng.normal() * 0.3;
        }
    }
    (HostValue::F32(x), HostValue::I32(y))
}

fn run_mode(mode: QuantMode, steps: u64) -> (f32, f32, Vec<u8>) {
    let mut rt = runtime().expect("runtime");
    let mut trainer = ArtifactTrainer::new(&rt, "mlp_train_step", mlp_slot_names(3), mode, 11).unwrap();
    let mut rng = Pcg32::seeded(5);
    let templates: Vec<f32> = {
        let mut t = vec![0.0f32; 10 * 64];
        let mut trng = Pcg32::seeded(99);
        trng.fill_normal(&mut t, 2.0);
        t
    };
    let mut first = 0.0;
    let mut last = 0.0;
    let mut bits = vec![];
    for s in 0..steps {
        let (x, y) = batch(&mut rng, &templates);
        let res = trainer.step(&mut rt, vec![x, y], 0.05).expect("step");
        if s == 0 {
            first = res.loss;
        }
        last = res.loss;
        bits = res.grad_bits;
    }
    (first, last, bits)
}

#[test]
fn adaptive_training_reduces_loss_e2e() {
    if runtime().is_none() {
        return;
    }
    let mut cfg = apt::apt::AptConfig::default();
    cfg.init_phase_iters = 3;
    let (first, last, bits) = run_mode(QuantMode::Adaptive(cfg), 40);
    assert!(
        last < first * 0.7,
        "adaptive e2e did not learn: {first} → {last}"
    );
    assert_eq!(bits.len(), 3);
    assert!(bits.iter().all(|b| [8, 16, 24, 32].contains(b)), "{bits:?}");
}

#[test]
fn float32_and_int16_also_learn_e2e() {
    if runtime().is_none() {
        return;
    }
    let (f1, f2, _) = run_mode(QuantMode::Float32, 30);
    assert!(f2 < f1 * 0.8, "f32 proxy: {f1} → {f2}");
    let (i1, i2, bits) = run_mode(QuantMode::Static(16), 30);
    assert!(i2 < i1 * 0.8, "int16: {i1} → {i2}");
    assert!(bits.iter().all(|&b| b == 16));
}

#[test]
fn mlp_eval_artifact_returns_sane_accuracy() {
    let Some(mut rt) = runtime() else { return };
    // random weights → accuracy near chance on random labels
    let spec = rt.manifest.get("mlp_eval").unwrap().clone();
    let mut rng = Pcg32::seeded(0);
    let mut inputs = Vec::new();
    for io in &spec.inputs {
        match io.dtype {
            apt::runtime::Dtype::F32 => {
                let mut v = vec![0.0f32; io.elements()];
                if io.dims.len() == 2 && io.name != "qparams" {
                    rng.fill_normal(&mut v, 0.1);
                }
                if io.name == "qparams" {
                    // wide scheme everywhere
                    let s = apt::fixedpoint::Scheme::for_range(4.0, 16);
                    let triple = [s.resolution(), s.qmin() as f32, s.qmax() as f32];
                    for row in 0..io.dims[0] {
                        for t in 0..3 {
                            v[row * 9 + t * 3..row * 9 + t * 3 + 3].copy_from_slice(&triple);
                        }
                    }
                }
                if io.name == "x" {
                    rng.fill_normal(&mut v, 1.0);
                }
                inputs.push(HostValue::F32(v));
            }
            apt::runtime::Dtype::I32 => {
                let v: Vec<i32> = (0..io.elements()).map(|_| rng.below(10) as i32).collect();
                inputs.push(HostValue::I32(v));
            }
        }
    }
    let out = rt.exec("mlp_eval", &inputs).expect("mlp_eval");
    let acc = out[0].scalar_f32();
    assert!((0.0..=1.0).contains(&acc), "acc={acc}");
}
