//! Randomized property battery for the gradient-compression seam
//! (DESIGN.md §Data-Parallel, `train::parallel::compress`), on the offline
//! proptest substitute `apt::util::proptest`:
//!
//! - **identity bit-parity** — `--compress none` round-trips every gradient
//!   bit-identically through compress ∘ decompress;
//! - **quantize = fake-quant** — the quantize compressor's round-trip
//!   equals the scheme's `fake_quant` per element (bit-exact), with the
//!   half-resolution error bound for in-range values;
//! - **top-k partition** — error feedback is an exact partition: every
//!   element of the corrected gradient lands bit-identically either in the
//!   payload or in the stored residual, never both (the -0.0-safe way of
//!   saying "residuals sum to exactly the withheld mass");
//! - **top-k selection bounds** — k = clamp(ceil(ratio·len), 1, len),
//!   indices ascending/unique/in-range, selected magnitudes dominate;
//! - **determinism** — same gradient sequence ⇒ byte-identical wire
//!   payloads from independently constructed compressors;
//! - **wire accounting** — `WirePayload::wire_bytes` equals the length of
//!   the canonical `encode()` serialization, and intra-node aggregation
//!   never exceeds the sum of member payloads;
//! - **hierarchical = flat** — `hier_reduce_f32` is bit-identical to
//!   `tree_reduce_f32` and to both independent oracles, for every replica
//!   count × power-of-two node size.

mod common;

use apt::apt::{AptConfig, Ledger};
use apt::fixedpoint::Scheme;
use apt::train::parallel::{
    aggregate_wire_bytes, hier_reduce_f32, top_k_indices, tree_reduce_f32, Compressor,
    IdentityCompressor, QuantizeCompressor, TopKCompressor, TopKQuantizeCompressor, WirePayload,
};
use apt::util::proptest::check;
use common::oracle::{oracle_hier, oracle_tree};

fn names(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("t.{i}")).collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_identity_bit_parity() {
    check("identity-bit-parity", 60, |g| {
        let len = g.usize(1, 300);
        let grad = g.normal_vec(len, *g.choose(&[0.01f32, 1.0, 100.0]));
        let mut c = IdentityCompressor;
        let corrected = c.corrected(0, 0, &grad);
        assert!(bits_eq(&corrected, &grad), "identity corrected() must not touch the gradient");
        let p = c.compress(0, 0, corrected);
        assert!(matches!(p, WirePayload::Dense(_)));
        assert!(
            bits_eq(&c.decompress(&p), &grad),
            "identity compress∘decompress must be bit-identical"
        );
    });
}

#[test]
fn prop_quantize_matches_fake_quant() {
    check("quantize-fake-quant", 60, |g| {
        let bits = *g.choose(&[8u8, 16]);
        let len = g.usize(1, 300);
        let grad = g.normal_vec(len, g.f32_log(1e-4, 10.0));
        let mut c = QuantizeCompressor::new(AptConfig::static_bits(bits), &names(1));
        let mut ledger = Ledger::new();
        c.begin_tensor(0, 0, &grad, &mut ledger);
        let p = c.compress(0, 0, grad.clone());
        let sch = p.scheme().expect("quantize payload carries its scheme");
        assert_eq!(sch.bits, bits);
        let dec = c.decompress(&p);
        let half = sch.resolution() * 0.5;
        for (i, (&d, &x)) in dec.iter().zip(&grad).enumerate() {
            assert_eq!(
                d.to_bits(),
                sch.fake_quant(x).to_bits(),
                "element {i}: decode must equal the scheme's fake_quant"
            );
            if x.abs() <= sch.range_top() {
                assert!(
                    (d - x).abs() <= half * 1.0001,
                    "element {i}: in-range error {} exceeds resolution/2 = {half}",
                    (d - x).abs()
                );
            }
        }
    });
}

#[test]
fn prop_topk_residual_partition() {
    // The -0.0-proof statement of residual conservation: compress splits
    // the corrected gradient into payload and residual *bitwise* — so the
    // withheld mass is exact by construction, not up to rounding.
    check("topk-residual-partition", 60, |g| {
        let len = g.usize(1, 200);
        let ratio = g.f32(0.01, 1.0);
        let grad = g.normal_vec(len, 1.0);
        let mut c = TopKCompressor::new(ratio);
        let corrected = c.corrected(0, 0, &grad);
        let p = c.compress(0, 0, corrected.clone());
        let (idx, val) = match &p {
            WirePayload::Sparse { len: l, idx, val } => {
                assert_eq!(*l, len);
                (idx.clone(), val.clone())
            }
            other => panic!("topk payload must be Sparse, got {other:?}"),
        };
        let res = c.residual_snapshot();
        assert_eq!(res.len(), 1);
        let (t, r, residual) = &res[0];
        assert_eq!((*t, *r), (0, 0));
        assert_eq!(residual.len(), len);

        let selected: std::collections::BTreeSet<u32> = idx.iter().copied().collect();
        for (j, &i) in idx.iter().enumerate() {
            assert_eq!(
                val[j].to_bits(),
                corrected[i as usize].to_bits(),
                "selected element {i} must move to the payload bit-identically"
            );
            assert_eq!(
                residual[i as usize].to_bits(),
                0.0f32.to_bits(),
                "selected element {i} must be zeroed in the residual"
            );
        }
        for i in 0..len {
            if !selected.contains(&(i as u32)) {
                assert_eq!(
                    residual[i].to_bits(),
                    corrected[i].to_bits(),
                    "unselected element {i} must stay in the residual bit-identically"
                );
            }
        }

        // …and the next step's correction applies exactly that residual.
        let grad2 = g.normal_vec(len, 1.0);
        let corrected2 = c.corrected(0, 0, &grad2);
        let expect: Vec<f32> = grad2.iter().zip(residual).map(|(a, b)| a + b).collect();
        assert!(bits_eq(&corrected2, &expect), "error feedback must add the stored residual");
    });
}

#[test]
fn prop_topk_quantize_keeps_the_partition() {
    // The composition feeds back only the sparsification error: its
    // residual is the same exact partition remainder as plain top-k
    // (quantization error stays on the wire, bounded by the controller).
    check("topk-quantize-partition", 40, |g| {
        let len = g.usize(1, 200);
        let ratio = g.f32(0.05, 0.9);
        let grad = g.normal_vec(len, 1.0);
        let mut plain = TopKCompressor::new(ratio);
        let mut composed =
            TopKQuantizeCompressor::new(AptConfig::static_bits(8), ratio, &names(1));
        let mut ledger = Ledger::new();
        composed.begin_tensor(0, 0, &grad, &mut ledger);
        let _ = plain.compress(0, 0, grad.clone());
        let p = composed.compress(0, 0, grad.clone());
        assert!(matches!(p, WirePayload::SparseCodes { .. }));
        assert_eq!(
            composed.residual_snapshot(),
            plain.residual_snapshot(),
            "composition must withhold exactly what plain top-k withholds"
        );
    });
}

#[test]
fn prop_topk_selection_bounds() {
    check("topk-selection-bounds", 80, |g| {
        let len = g.usize(1, 400);
        let ratio = g.f32(0.001, 1.0);
        let v = g.normal_vec(len, g.f32_log(1e-3, 1e3));
        let idx = top_k_indices(&v, ratio);
        let k = ((ratio as f64 * len as f64).ceil() as usize).clamp(1, len);
        assert_eq!(idx.len(), k, "k must be clamp(ceil(ratio·len), 1, len)");
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices ascending and unique");
        assert!(idx.iter().all(|&i| (i as usize) < len), "indices in range");
        let selected: std::collections::BTreeSet<u32> = idx.iter().copied().collect();
        let min_sel = idx.iter().map(|&i| v[i as usize].abs()).fold(f32::INFINITY, f32::min);
        let max_unsel = (0..len as u32)
            .filter(|i| !selected.contains(i))
            .map(|i| v[i as usize].abs())
            .fold(0.0f32, f32::max);
        assert!(
            min_sel >= max_unsel,
            "selected magnitudes must dominate: min selected {min_sel} < max unselected {max_unsel}"
        );
    });
}

#[test]
fn prop_wire_payloads_are_deterministic() {
    // Two independently constructed compressors fed the same gradient
    // sequence must emit byte-identical wire payloads — selection,
    // scheme probing and packing are all pure functions of the input.
    check("wire-determinism", 30, |g| {
        let len = g.usize(1, 120);
        let ratio = g.f32(0.05, 0.9);
        let steps: Vec<Vec<f32>> = (0..3).map(|_| g.normal_vec(len, 1.0)).collect();
        let run = |mut c: Box<dyn Compressor>| -> Vec<u8> {
            let mut ledger = Ledger::new();
            let mut bytes = Vec::new();
            for (it, grad) in steps.iter().enumerate() {
                let corrected = c.corrected(0, 0, grad);
                c.begin_tensor(it as u64, 0, &corrected, &mut ledger);
                bytes.extend(c.compress(0, 0, corrected).encode());
            }
            bytes
        };
        let cfg = AptConfig::static_bits(8);
        let pairs: Vec<(Box<dyn Compressor>, Box<dyn Compressor>)> = vec![
            (Box::new(IdentityCompressor), Box::new(IdentityCompressor)),
            (
                Box::new(QuantizeCompressor::new(cfg, &names(1))),
                Box::new(QuantizeCompressor::new(cfg, &names(1))),
            ),
            (Box::new(TopKCompressor::new(ratio)), Box::new(TopKCompressor::new(ratio))),
            (
                Box::new(TopKQuantizeCompressor::new(cfg, ratio, &names(1))),
                Box::new(TopKQuantizeCompressor::new(cfg, ratio, &names(1))),
            ),
        ];
        for (a, b) in pairs {
            let label = a.label();
            assert_eq!(run(a), run(b), "{label}: wire payloads diverged across twins");
        }
    });
}

#[test]
fn prop_wire_bytes_match_encoding() {
    check("wire-bytes-accounting", 60, |g| {
        let len = g.usize(1, 120);
        let sch = Scheme { bits: *g.choose(&[8u8, 16]), s: g.int(-12, 2) as i32 };
        let vals = g.normal_vec(len, 1.0);
        let codes: Vec<i32> = vals.iter().map(|&x| sch.code(x)).collect();
        let k = g.usize(1, len);
        let idx: Vec<u32> = (0..k as u32).collect();
        let payloads = vec![
            WirePayload::Dense(vals.clone()),
            WirePayload::Codes { scheme: sch, codes: codes.clone() },
            WirePayload::Sparse { len, idx: idx.clone(), val: vals[..k].to_vec() },
            WirePayload::SparseCodes { len, scheme: sch, idx, codes: codes[..k].to_vec() },
        ];
        for p in &payloads {
            assert_eq!(
                p.wire_bytes(),
                p.encode().len() as u64,
                "wire_bytes must equal the canonical encoding length"
            );
            // intra-node aggregation is never more expensive than sending
            // the members individually
            let node: Vec<WirePayload> = vec![p.clone(), p.clone()];
            assert!(aggregate_wire_bytes(&node) <= 2 * p.wire_bytes());
        }
    });
}

#[test]
fn prop_hierarchical_reduce_matches_flat_and_oracles() {
    check("hier-flat-oracle", 60, |g| {
        let n = g.usize(1, 17);
        let len = g.usize(1, 120);
        let parts: Vec<Vec<f32>> =
            (0..n).map(|_| g.normal_vec(len, g.f32_log(1e-2, 1e2))).collect();
        let refs: Vec<&[f32]> = parts.iter().map(|p| p.as_slice()).collect();
        let flat = tree_reduce_f32(&refs);
        assert!(
            bits_eq(&flat, &oracle_tree(&parts)),
            "production ladder diverged from the recursive oracle at n={n}"
        );
        for node in [1usize, 2, 4, 8, 16] {
            assert!(
                bits_eq(&hier_reduce_f32(&refs, node), &flat),
                "hier(node={node}) diverged from flat at n={n}"
            );
            assert!(
                bits_eq(&oracle_hier(&parts, node), &flat),
                "oracle hier(node={node}) diverged from flat at n={n}"
            );
        }
    });
}
