//! Integration: PJRT round-trips of the L1 kernel artifacts, cross-checked
//! against the Rust `fixedpoint` implementation — the cross-language
//! bit-exactness contract between `kernels/ref.py`, the Pallas kernels, and
//! the Rust substrate.
//!
//! Requires `make artifacts` (skips gracefully otherwise so `cargo test`
//! stays green on a fresh checkout).

use apt::fixedpoint::quantize::{max_abs, stats_only};
use apt::fixedpoint::{gemm, Scheme};
use apt::runtime::{HostValue, Runtime};
use apt::util::Pcg32;

fn runtime() -> Option<Runtime> {
    match Runtime::new("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

fn randvec(seed: u64, n: usize, scale: f32) -> Vec<f32> {
    let mut r = Pcg32::seeded(seed);
    (0..n).map(|_| r.normal() * scale).collect()
}

#[test]
fn quant_fake_artifact_matches_rust_scheme() {
    let Some(mut rt) = runtime() else { return };
    let x = randvec(1, 64 * 64, 2.0);
    let sch = Scheme::for_range(max_abs(&x), 8);
    let params = vec![sch.resolution(), sch.qmin() as f32, sch.qmax() as f32];
    let out = rt
        .exec("quant_fake", &[HostValue::F32(x.clone()), HostValue::F32(params)])
        .expect("exec quant_fake");
    let got = out[0].as_f32();
    for (i, (&g, &v)) in got.iter().zip(&x).enumerate() {
        let want = sch.fake_quant(v);
        assert_eq!(g, want, "elem {i}: pallas {g} vs rust {want} (x={v})");
    }
}

#[test]
fn qem_stats_artifact_matches_rust_stats() {
    let Some(mut rt) = runtime() else { return };
    let x = randvec(2, 64 * 64, 1.5);
    let z = max_abs(&x);
    let sch = Scheme::for_range(z, 8);
    let params = vec![sch.resolution(), sch.qmin() as f32, sch.qmax() as f32, z];
    let out = rt
        .exec("qem_stats", &[HostValue::F32(x.clone()), HostValue::F32(params)])
        .expect("exec qem_stats");
    let s = out[0].as_f32();
    let want = stats_only(&x, sch);
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-9);
    assert!(rel(s[0] as f64, want.sum_abs) < 1e-4, "sum_abs {} vs {}", s[0], want.sum_abs);
    assert_eq!(s[1], want.max_abs);
    assert!(rel(s[2] as f64, want.sum_abs_q) < 1e-4, "sum_abs_q {} vs {}", s[2], want.sum_abs_q);
    // candidate columns: int8/int16/int24 sums under range-derived schemes
    for (idx, bits) in [(3usize, 8u8), (4, 16), (5, 24)] {
        let c = Scheme::for_range(z, bits);
        let w = stats_only(&x, c).sum_abs_q;
        assert!(rel(s[idx] as f64, w) < 1e-4, "cand int{bits}: {} vs {w}", s[idx]);
    }
}

#[test]
fn qmatmul_artifact_matches_rust_qgemm() {
    let Some(mut rt) = runtime() else { return };
    let (m, k, n) = (64usize, 64, 64);
    let a = randvec(3, m * k, 1.0);
    let b = randvec(4, k * n, 0.3);
    let sa = Scheme::for_range(max_abs(&a), 8);
    let sb = Scheme::for_range(max_abs(&b), 8);
    let params = vec![
        sa.resolution(),
        sa.qmin() as f32,
        sa.qmax() as f32,
        sb.resolution(),
        sb.qmin() as f32,
        sb.qmax() as f32,
    ];
    let out = rt
        .exec(
            "qmatmul",
            &[HostValue::F32(a.clone()), HostValue::F32(b.clone()), HostValue::F32(params)],
        )
        .expect("exec qmatmul");
    let got = out[0].as_f32();
    let mut want = vec![0.0f32; m * n];
    gemm::qgemm(m, k, n, &a, sa, &b, sb, &mut want);
    for (i, (&g, &w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-4 * w.abs().max(1.0),
            "elem {i}: pallas {g} vs rust {w}"
        );
    }
}

#[test]
fn manifest_lists_all_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    for name in ["quant_fake", "qem_stats", "qmatmul", "mlp_train_step", "mlp_eval", "tfm_train_step"] {
        assert!(rt.manifest.get(name).is_some(), "missing artifact {name}");
    }
}

#[test]
fn exec_rejects_wrong_arity_and_shape() {
    let Some(mut rt) = runtime() else { return };
    assert!(rt.exec("quant_fake", &[]).is_err());
    let bad = vec![HostValue::F32(vec![0.0; 3]), HostValue::F32(vec![0.0; 3])];
    assert!(rt.exec("quant_fake", &bad).is_err());
}
