//! `serve::` contract tests (DESIGN.md §Serving):
//!
//! 1. **Frozen parity** — `FrozenModel::from_checkpoint` logits are
//!    bit-identical to `Session::eval_logits` on mlp/alexnet checkpoints
//!    in Float32 and Static(8) modes (the int8 serving path runs integer
//!    GEMMs, yet lands on the same bits — the exactness argument in the
//!    `serve::frozen` module docs). Wider/BN-heavy models agree to float
//!    rounding.
//! 2. **Serving tier** (DESIGN.md §Serving-Tier) — responses are never
//!    mis-paired under concurrent pipelined submitters (both scheduler
//!    policies), backpressure blocks rather than drops, shutdown answers
//!    every accepted request exactly once (logits or an explicit
//!    `Shutdown` rejection), 10× overload neither deadlocks nor poisons
//!    the queue, warm swap pins each request to its admission-time model
//!    version bit-identically, a panicking worker rejects its batch
//!    instead of hanging it, and priority/deadline shedding is explicit.

use std::sync::Arc;
use std::time::Duration;

use apt::data::SynthImages;
use apt::kernels::Engine;
use apt::nn::{models, QuantMode};
use apt::serve::{
    FrozenModel, InferenceServer, ModelRegistry, SchedPolicy, ServeConfig, ServeModel,
    ServeOutcome, ShedReason, SubmitOpts,
};
use apt::tensor::Tensor;
use apt::train::SessionBuilder;

const POLICIES: [SchedPolicy; 2] = [SchedPolicy::Flush, SchedPolicy::Continuous];

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_serve_ckpt_{tag}_{}.txt", std::process::id()))
}

/// Builder-default eval batch: the stream `Session::eval` reads.
fn eval_batch(n: usize) -> (Tensor, Vec<usize>) {
    let data = SynthImages::new(
        1000, // builder default: seed 0 + 1000
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    data.eval_set(999, n)
}

/// Train briefly, checkpoint, and return (session logits on a fixed eval
/// batch, frozen-model logits on the same batch, frozen model).
fn session_vs_frozen(
    model: &str,
    mode: QuantMode,
    iters: u64,
) -> (Tensor, Tensor, FrozenModel) {
    let path = ckpt_path(&format!("{model}_{}", mode.label()));
    let mut s = SessionBuilder::classifier(model).mode(mode).lr(0.01).build();
    s.run(iters).unwrap();
    s.save_checkpoint(&path).unwrap();

    // Reload into a fresh session (the same rebuild path a deployment
    // would use) and evaluate.
    let mut s2 = SessionBuilder::classifier(model).mode(mode).lr(0.01).build();
    s2.load_checkpoint(&path).unwrap();
    let (ex, _) = eval_batch(64);
    let want = s2.eval_logits(&ex);

    let frozen = FrozenModel::from_checkpoint(&path, model, mode).unwrap();
    let got = frozen.forward(&ex, apt::kernels::global());
    let _ = std::fs::remove_file(&path);
    (want, got, frozen)
}

fn assert_bits_equal(want: &Tensor, got: &Tensor, tag: &str) {
    assert_eq!(want.shape, got.shape, "{tag}: shape");
    for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag}: logit {i} diverged ({a} vs {b})"
        );
    }
}

fn max_rel_err(want: &Tensor, got: &Tensor) -> f32 {
    let scale = want.max_abs().max(1e-12);
    want.data
        .iter()
        .zip(&got.data)
        .map(|(a, b)| (a - b).abs() / scale)
        .fold(0.0f32, f32::max)
}

#[test]
fn frozen_bit_exact_f32_mlp_alexnet() {
    for model in ["mlp", "alexnet"] {
        let (want, got, frozen) = session_vs_frozen(model, QuantMode::Float32, 25);
        assert_eq!(frozen.precision(), "f32");
        assert_bits_equal(&want, &got, &format!("{model}-f32"));
    }
}

#[test]
fn frozen_bit_exact_int8_mlp_alexnet() {
    // The serving path runs i8 codes through the integer GEMM + one
    // rescale; with 8-bit schemes and k ≤ 1024 every sum is exact in both
    // paths, so this asserts *bit* equality, not closeness.
    for model in ["mlp", "alexnet"] {
        let (want, got, frozen) = session_vs_frozen(model, QuantMode::Static(8), 25);
        assert_eq!(frozen.precision(), "int8");
        assert_bits_equal(&want, &got, &format!("{model}-int8"));
    }
}

#[test]
fn frozen_close_on_wider_and_bn_models() {
    // int16: the session's fake-quant reference accumulates >24-bit
    // products in f32, so the (exact) integer path differs in float
    // rounding only.
    let (want, got, frozen) = session_vs_frozen("mlp", QuantMode::Static(16), 25);
    assert_eq!(frozen.precision(), "int16");
    let e = max_rel_err(&want, &got);
    assert!(e < 1e-3, "mlp-int16 rel err {e}");

    // BN/residual/inception/depthwise model families through the frozen
    // stack-op path (folded BN running stats, branch merge, add-back).
    for (model, mode) in [
        ("resnet", QuantMode::Float32),
        ("resnet", QuantMode::Static(8)),
        ("mobilenet", QuantMode::Static(8)),
        ("inception", QuantMode::Static(8)),
        ("vgg", QuantMode::Static(8)),
    ] {
        let (want, got, _) = session_vs_frozen(model, mode, 12);
        let e = max_rel_err(&want, &got);
        assert!(e < 1e-4, "{model}-{}: rel err {e}", mode.label());
    }
}

#[test]
fn frozen_from_live_net_matches_checkpoint_route() {
    let path = ckpt_path("live");
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Static(8)).build();
    s.run(15).unwrap();
    s.save_checkpoint(&path).unwrap();
    let via_ckpt = FrozenModel::from_checkpoint(&path, "mlp", QuantMode::Static(8)).unwrap();
    let via_net = FrozenModel::freeze("mlp-int8", s.net()).unwrap();
    let (ex, _) = eval_batch(16);
    let eng = Engine::serial();
    assert_bits_equal(&via_net.forward(&ex, &eng), &via_ckpt.forward(&ex, &eng), "live-vs-ckpt");
    let _ = std::fs::remove_file(&path);
}

fn quick_frozen_mlp() -> FrozenModel {
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Static(8)).build();
    s.run(10).unwrap();
    FrozenModel::freeze("mlp-int8", s.net()).unwrap()
}

#[test]
fn server_pairs_responses_under_concurrent_submitters_both_policies() {
    let frozen = Arc::new(quick_frozen_mlp());
    let eng = Arc::new(Engine::serial());
    let clients = 4usize;
    let per_client = 10usize;
    let mut data = SynthImages::new(
        7,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let d = frozen.input_len();
    let (xs, _) = data.batch(clients * per_client);

    // Both scheduler policies must keep logits bit-identical to the
    // single-sample oracle — batching strategy is a latency decision,
    // never a numerics decision.
    for policy in POLICIES {
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait_us: 2_000,
            queue_cap: 64,
            workers: 2,
            policy,
            ..ServeConfig::default()
        };
        let server = InferenceServer::start(Arc::clone(&frozen), Arc::clone(&eng), cfg).unwrap();

        std::thread::scope(|scope| {
            for c in 0..clients {
                let server = &server;
                let frozen = &frozen;
                let eng = &eng;
                let xs = &xs;
                scope.spawn(move || {
                    // Pipelined: submit the whole slice, then resolve in order;
                    // every response must be the logits of *its own* input
                    // (batched rows are computed independently, so single-
                    // sample forward is the exact oracle).
                    let mut pendings = Vec::new();
                    for i in 0..per_client {
                        let idx = c * per_client + i;
                        pendings.push((idx, server.submit(xs.data[idx * d..(idx + 1) * d].to_vec()).unwrap()));
                    }
                    for (idx, p) in pendings {
                        let got = p.wait().unwrap();
                        let want = frozen.forward_one(&xs.data[idx * d..(idx + 1) * d], eng);
                        assert_eq!(got.len(), want.len());
                        for (a, b) in got.iter().zip(&want) {
                            assert_eq!(a.to_bits(), b.to_bits(), "request {idx} got another sample's logits");
                        }
                    }
                });
            }
        });

        let stats = server.shutdown();
        let tag = policy.label();
        assert_eq!(stats.accepted, (clients * per_client) as u64, "{tag}");
        assert_eq!(stats.served, (clients * per_client) as u64, "{tag}");
        assert!(stats.batches <= stats.served, "{tag}: batches {} > served {}", stats.batches, stats.served);
        assert!(stats.mean_batch() >= 1.0, "{tag}");
    }
}

#[test]
fn server_backpressure_bounded_queue_never_drops() {
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    // Tiny queue, one worker: concurrent blocking submitters must ride the
    // backpressure seam — block while full, never drop, never deadlock —
    // and the queue_cap < max_batch clamp must flush full queues instead
    // of waiting out the deadline (fill target = min(max_batch, queue_cap)).
    let cfg =
        ServeConfig { max_batch: 8, max_wait_us: 50_000, queue_cap: 2, workers: 1, ..ServeConfig::default() };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg).unwrap();
    let (threads, per) = (6usize, 8usize);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let server = &server;
            scope.spawn(move || {
                for _ in 0..per {
                    server.submit(vec![0.4; d]).unwrap().wait().unwrap();
                }
            });
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.accepted, (threads * per) as u64);
    assert_eq!(stats.served, (threads * per) as u64);
}

#[test]
fn try_submit_reports_full_queue_and_answers_all_accepted() {
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    // One worker, per-request batches, cap 2: a burst far faster than the
    // worker drains must hit the bounded-queue error on some submissions;
    // every accepted one must still be answered.
    let cfg =
        ServeConfig { max_batch: 1, max_wait_us: 0, queue_cap: 2, workers: 1, ..ServeConfig::default() };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg).unwrap();
    let burst = 200usize;
    let mut pendings = Vec::new();
    let mut full_errors = 0usize;
    for _ in 0..burst {
        match server.try_submit(vec![0.3; d]) {
            Ok(p) => pendings.push(p),
            Err(e) => {
                assert!(e.to_string().contains("full"), "unexpected error: {e}");
                full_errors += 1;
            }
        }
    }
    let accepted = pendings.len();
    assert_eq!(accepted + full_errors, burst);
    for p in pendings {
        p.wait().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.accepted, accepted as u64);
    assert_eq!(stats.served, accepted as u64);
    // A µs-scale burst against ms-scale forwards must engage the bound.
    assert!(full_errors > 0, "bounded queue never filled under a {burst}-deep burst");
}

#[test]
fn server_shutdown_answers_every_accepted_request_exactly_once() {
    // Shutdown semantics (DESIGN.md §Serving-Tier): in-flight batches
    // drain and answer normally; requests still queued get an explicit
    // `Shutdown` rejection — nothing hangs, nothing is silently dropped,
    // and the accounting invariant accepted == served + shed holds.
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    let cfg =
        ServeConfig { max_batch: 4, max_wait_us: 200_000, queue_cap: 64, workers: 1, ..ServeConfig::default() };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg).unwrap();
    let pendings: Vec<_> = (0..9).map(|_| server.submit(vec![0.5; d]).unwrap()).collect();
    let stats = server.shutdown(); // close + drain in-flight + reject queued + join
    assert_eq!(stats.accepted, 9);
    assert!(
        stats.accounted(),
        "accepted {} != served {} + shed {}",
        stats.accepted,
        stats.served,
        stats.shed
    );
    let (mut served, mut rejected) = (0u64, 0u64);
    for p in pendings {
        match p.outcome().unwrap() {
            ServeOutcome::Logits(l) => {
                assert_eq!(l.len(), models::CLASSES);
                served += 1;
            }
            ServeOutcome::Shed(ShedReason::Shutdown) => rejected += 1,
            ServeOutcome::Shed(r) => panic!("unexpected shed reason {r:?}"),
        }
    }
    assert_eq!(served, stats.served);
    assert_eq!(rejected, stats.shed);
    assert_eq!(served + rejected, 9);
}

#[test]
fn server_rejects_wrong_input_width_and_unknown_model() {
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    let server =
        InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), ServeConfig::default())
            .unwrap();
    assert!(server.submit(vec![0.0; 3]).is_err());
    assert!(server.try_submit(vec![]).is_err());
    let opts = SubmitOpts { model: Some("no-such-model".into()), ..SubmitOpts::default() };
    let err = server.submit_opts(vec![0.0; d], opts).unwrap_err().to_string();
    assert!(err.contains("no-such-model"), "unexpected error: {err}");
}

#[test]
fn server_rejects_degenerate_configs_with_typed_errors() {
    // CLI-reachable config mistakes (--workers 0, --max-batch 0, …) must
    // surface as Err, never as a panic inside the serving tier (the
    // unwrap-audit contract).
    let frozen = Arc::new(quick_frozen_mlp());
    for (cfg, what) in [
        (ServeConfig { workers: 0, ..ServeConfig::default() }, "worker"),
        (ServeConfig { max_batch: 0, ..ServeConfig::default() }, "max_batch"),
        (ServeConfig { queue_cap: 0, ..ServeConfig::default() }, "queue_cap"),
        (ServeConfig { lanes: 0, ..ServeConfig::default() }, "lane"),
    ] {
        let err = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg)
            .err()
            .map(|e| e.to_string())
            .unwrap_or_else(|| panic!("{what}: degenerate config must be rejected"));
        assert!(err.contains(what), "{what}: unexpected error {err}");
    }
}

#[test]
fn freeze_infers_geometry_and_labels() {
    let frozen = quick_frozen_mlp();
    assert_eq!(frozen.input_len(), models::input_len());
    assert_eq!(frozen.label(), "mlp-int8");
    let logits = frozen.forward_one(&vec![0.0; frozen.input_len()], &Engine::serial());
    assert_eq!(logits.len(), models::CLASSES);
}

// ---- serving tier: registry, overload, warm swap, panic, shedding ----

/// A scripted [`ServeModel`] for failure-path and scheduling tests:
/// optional fixed service time, optional poison input that panics the
/// forward, and an affine output (`y_j = x_0 · scale + j`) that encodes
/// the input so response pairing stays checkable with exact math.
struct TestModel {
    din: usize,
    dout: usize,
    sleep_ms: u64,
    panic_on: Option<f32>,
    scale: f32,
}

impl ServeModel for TestModel {
    fn input_len(&self) -> usize {
        self.din
    }

    fn forward(&self, x: &Tensor, _eng: &Engine) -> Tensor {
        if self.sleep_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.sleep_ms));
        }
        let n = x.shape[0];
        let mut y = Tensor::zeros(&[n, self.dout]);
        for i in 0..n {
            let x0 = x.data[i * self.din];
            if self.panic_on.map_or(false, |p| x0 == p) {
                panic!("test model hit its poison input");
            }
            for j in 0..self.dout {
                y.data[i * self.dout + j] = x0 * self.scale + j as f32;
            }
        }
        y
    }

    fn label(&self) -> &str {
        "test-model"
    }
}

/// `TestModel`'s expected logits for input row `[x0, ..]` at scale 1.
fn affine(x0: f32, dout: usize) -> Vec<f32> {
    (0..dout).map(|j| x0 + j as f32).collect()
}

fn test_server(m: TestModel, cfg: ServeConfig) -> InferenceServer {
    let registry = Arc::new(ModelRegistry::new());
    registry.publish("m", 1, Arc::new(m) as Arc<dyn ServeModel>).unwrap();
    InferenceServer::start_registry(registry, "m", Arc::new(Engine::serial()), cfg).unwrap()
}

#[test]
fn registry_lifecycle_publish_activate_evict() {
    let reg = ModelRegistry::new();
    let m = |s: f32| {
        Arc::new(TestModel { din: 2, dout: 2, sleep_ms: 0, panic_on: None, scale: s })
            as Arc<dyn ServeModel>
    };
    reg.publish("m", 1, m(1.0)).unwrap();
    reg.publish("m", 2, m(2.0)).unwrap(); // warm swap: 2 is now active
    assert!(reg.publish("m", 2, m(3.0)).is_err(), "versions are immutable");
    assert_eq!(reg.resolve("m").unwrap().0, 2);
    assert!(reg.resolve("absent").is_none());
    reg.activate("m", 1).unwrap(); // rollback
    assert_eq!(reg.resolve("m").unwrap().0, 1);
    assert!(reg.evict("m", 1).is_err(), "the active version is protected");
    reg.evict("m", 2).unwrap();
    assert!(reg.resolve_version("m", 2).is_none());
    assert_eq!(reg.loaded(), 1);
    let info = &reg.list()[0];
    assert_eq!((info.name.as_str(), info.active, info.versions.as_slice()), ("m", 1, &[1u64][..]));
    reg.evict_model("m").unwrap();
    assert_eq!(reg.loaded(), 0);
}

#[test]
fn overload_10x_resolves_every_request_without_deadlock() {
    // 4 threads blast 200 requests each through the never-blocking path
    // at a server whose capacity is far below the burst rate: the bounded
    // queue must shed explicitly (admission errors or shed outcomes),
    // every accepted request must resolve, and the server must still be
    // healthy afterwards. Run under both policies.
    for policy in POLICIES {
        let m = TestModel { din: 4, dout: 3, sleep_ms: 1, panic_on: None, scale: 1.0 };
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait_us: 100,
            queue_cap: 8,
            workers: 2,
            policy,
            lanes: 3,
        };
        let server = test_server(m, cfg);
        let (threads, per) = (4usize, 200usize);
        let accepted_by_clients: u64 = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for t in 0..threads {
                let server = &server;
                handles.push(scope.spawn(move || {
                    let mut pendings = Vec::new();
                    for i in 0..per {
                        let opts = SubmitOpts {
                            lane: (t + i) % 3,
                            deadline_us: if i % 2 == 0 { Some(50_000) } else { None },
                            model: None,
                        };
                        if let Ok(p) = server.submit_opts(vec![0.25; 4], opts) {
                            pendings.push(p);
                        }
                    }
                    let n = pendings.len() as u64;
                    for p in pendings {
                        // Logits or an explicit shed — never a hang, never
                        // a dropped channel.
                        p.outcome().unwrap();
                    }
                    n
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        // The queue lock survived the storm: a plain request still serves.
        let got = server.submit(vec![0.5; 4]).unwrap().wait().unwrap();
        assert_eq!(got, affine(0.5, 3));
        let stats = server.shutdown();
        let tag = policy.label();
        assert_eq!(stats.accepted, accepted_by_clients + 1, "{tag}");
        assert_eq!(stats.submitted(), (threads * per) as u64 + 1, "{tag}");
        assert!(
            stats.accounted(),
            "{tag}: accepted {} != served {} + shed {}",
            stats.accepted,
            stats.served,
            stats.shed
        );
    }
}

#[test]
fn warm_swap_pins_admission_time_version_bit_identically() {
    // Train two checkpoints of the same architecture (v2 = 10 more
    // steps), publish v1, admit requests, warm-swap to v2 mid-stream,
    // admit more. Every request must come back with logits bit-identical
    // to the single-sample forward of the version that was active when
    // *it* was admitted — in-flight and queued v1 requests drain on v1,
    // no queue flush. max_batch 8 with a long hold makes the two
    // admission waves land in one dispatch, exercising the per-version
    // batch split (versions never share a tensor).
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Static(8)).build();
    s.run(10).unwrap();
    let v1 = Arc::new(FrozenModel::freeze("mlp-v1", s.net()).unwrap());
    s.run(10).unwrap();
    let v2 = Arc::new(FrozenModel::freeze("mlp-v2", s.net()).unwrap());
    let eng = Arc::new(Engine::serial());
    let d = v1.input_len();

    let registry = Arc::new(ModelRegistry::new());
    registry.publish("mlp", 1, Arc::clone(&v1) as Arc<dyn ServeModel>).unwrap();
    let cfg = ServeConfig { max_batch: 8, max_wait_us: 50_000, workers: 1, ..ServeConfig::default() };
    let server =
        InferenceServer::start_registry(Arc::clone(&registry), "mlp", Arc::clone(&eng), cfg).unwrap();

    let mut data = SynthImages::new(11, models::CLASSES, models::IN_C, models::IN_H, models::IN_W, 0.5);
    let (xs, _) = data.batch(8);
    let row = |i: usize| xs.data[i * d..(i + 1) * d].to_vec();

    let first: Vec<_> = (0..4).map(|i| server.submit(row(i)).unwrap()).collect();
    registry.publish("mlp", 2, Arc::clone(&v2) as Arc<dyn ServeModel>).unwrap();
    assert_eq!(registry.resolve("mlp").unwrap().0, 2, "publish flips the active version");
    let second: Vec<_> = (4..8).map(|i| server.submit(row(i)).unwrap()).collect();

    for (wave, (offset, oracle)) in [(first, (0usize, &v1)), (second, (4usize, &v2))] {
        for (k, p) in wave.into_iter().enumerate() {
            let i = offset + k;
            let want = oracle.forward_one(&row(i), &eng);
            let got = p.wait().unwrap();
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "request {i} ran on the wrong version");
            }
        }
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 8);
    // Retiring the drained v1 is now legal; v2 keeps serving.
    registry.evict("mlp", 1).unwrap();
    assert_eq!(registry.loaded(), 1);
}

#[test]
fn worker_panic_rejects_request_instead_of_hanging() {
    let m = TestModel { din: 4, dout: 3, sleep_ms: 0, panic_on: Some(-1.0), scale: 1.0 };
    let cfg = ServeConfig {
        max_batch: 4,
        max_wait_us: 100,
        queue_cap: 16,
        workers: 1,
        policy: SchedPolicy::Continuous,
        lanes: 3,
    };
    let server = test_server(m, cfg);
    // Poison forward: the client gets an explicit worker-panic error,
    // not a hang and not a poisoned queue lock...
    let p = server.submit(vec![-1.0, 0.0, 0.0, 0.0]).unwrap();
    let err = p.wait().unwrap_err().to_string();
    assert!(err.contains("worker-panic"), "unexpected error: {err}");
    // ...and the same worker keeps serving.
    let got = server.submit(vec![2.0, 0.0, 0.0, 0.0]).unwrap().wait().unwrap();
    assert_eq!(got, affine(2.0, 3));
    let stats = server.shutdown();
    assert!(stats.accounted());
    assert_eq!((stats.served, stats.shed), (1, 1));
}

#[test]
fn worker_panic_mid_batch_answers_every_member() {
    // Kill a worker mid-batch: occupy the single worker, queue a batch
    // containing one poison row, and require every member to resolve —
    // the poison request always fails with worker-panic; batch-mates
    // either died with it (same batch) or served normally (dispatch
    // raced ahead). No outcome may be a hang.
    let m = TestModel { din: 2, dout: 2, sleep_ms: 20, panic_on: Some(-1.0), scale: 1.0 };
    let cfg = ServeConfig {
        max_batch: 8,
        max_wait_us: 0,
        queue_cap: 16,
        workers: 1,
        policy: SchedPolicy::Continuous,
        lanes: 3,
    };
    let server = test_server(m, cfg);
    let a = server.submit(vec![1.0, 0.0]).unwrap();
    std::thread::sleep(Duration::from_millis(5)); // worker is mid-forward on `a`
    let wave: Vec<_> =
        [-1.0f32, 2.0, 3.0].iter().map(|&v| (v, server.submit(vec![v, 0.0]).unwrap())).collect();
    assert_eq!(a.wait().unwrap(), affine(1.0, 2));
    for (v, p) in wave {
        match p.outcome().unwrap() {
            ServeOutcome::Logits(l) => {
                assert!(v != -1.0, "poison input must not produce logits");
                assert_eq!(l, affine(v, 2));
            }
            ServeOutcome::Shed(ShedReason::WorkerPanic) => {}
            ServeOutcome::Shed(r) => panic!("unexpected shed reason {r:?}"),
        }
    }
    let stats = server.shutdown();
    assert!(stats.accounted());
    assert!(stats.shed >= 1, "the poison request must be counted shed");
}

#[test]
fn priority_eviction_sheds_lowest_lane_explicitly() {
    // One slow worker, cap-2 queue: an urgent arrival on a full queue
    // displaces the youngest background request (explicit Evicted reply),
    // and a background arrival with nobody below it is refused.
    let m = TestModel { din: 2, dout: 2, sleep_ms: 40, panic_on: None, scale: 1.0 };
    let cfg = ServeConfig {
        max_batch: 1,
        max_wait_us: 0,
        queue_cap: 2,
        workers: 1,
        policy: SchedPolicy::Continuous,
        lanes: 3,
    };
    let server = test_server(m, cfg);
    let lane = |l: usize| SubmitOpts { lane: l, ..SubmitOpts::default() };
    let a = server.submit_opts(vec![1.0, 0.0], lane(1)).unwrap(); // dispatched at once
    std::thread::sleep(Duration::from_millis(10)); // worker now busy ~40 ms
    let b = server.submit_opts(vec![2.0, 0.0], lane(2)).unwrap(); // queued
    let c = server.submit_opts(vec![3.0, 0.0], lane(2)).unwrap(); // queued; queue full
    let d = server.submit_opts(vec![4.0, 0.0], lane(0)).unwrap(); // evicts c
    match c.outcome().unwrap() {
        ServeOutcome::Shed(ShedReason::Evicted) => {}
        other => panic!("expected eviction, got {other:?}"),
    }
    let err = server.submit_opts(vec![5.0, 0.0], lane(2)).unwrap_err().to_string();
    assert!(err.contains("queue-full"), "unexpected error: {err}");
    assert_eq!(a.wait().unwrap(), affine(1.0, 2));
    assert_eq!(d.wait().unwrap(), affine(4.0, 2)); // urgent lane runs first
    assert_eq!(b.wait().unwrap(), affine(2.0, 2));
    let stats = server.shutdown();
    assert!(stats.accounted());
    assert_eq!((stats.served, stats.shed, stats.shed_admission), (3, 1, 1));
}

#[test]
fn deadlines_shed_on_admission_and_expire_at_dispatch() {
    let m = TestModel { din: 2, dout: 2, sleep_ms: 30, panic_on: None, scale: 1.0 };
    let cfg = ServeConfig {
        max_batch: 1,
        max_wait_us: 0,
        queue_cap: 64,
        workers: 1,
        policy: SchedPolicy::Continuous,
        lanes: 3,
    };
    let server = test_server(m, cfg);
    // Prime the service-time EWMA (feasibility admits everything until
    // the first batch lands).
    server.submit(vec![0.0, 0.0]).unwrap().wait().unwrap();
    // Occupy the worker with an undeadlined request; the queue is empty,
    // so a tight-deadline request is *admitted* (nothing queued ahead)…
    let busy = server.submit(vec![1.0, 0.0]).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let doomed = server
        .submit_opts(vec![2.0, 0.0], SubmitOpts { deadline_us: Some(200), ..SubmitOpts::default() })
        .unwrap();
    // …then a backlog builds behind it, and a 1 ms deadline behind ~6
    // requests × ~30 ms each is refused at admission.
    let backlog: Vec<_> = (0..5).map(|i| server.submit(vec![3.0 + i as f32, 0.0]).unwrap()).collect();
    let err = server
        .submit_opts(vec![9.0, 0.0], SubmitOpts { deadline_us: Some(1_000), ..SubmitOpts::default() })
        .unwrap_err()
        .to_string();
    assert!(err.contains("deadline-unmeetable"), "unexpected error: {err}");
    // The admitted tight-deadline request expired while the worker was
    // busy: it is dropped at dispatch with an explicit reply, not run late.
    match doomed.outcome().unwrap() {
        ServeOutcome::Shed(ShedReason::DeadlineExpired) => {}
        other => panic!("expected dispatch-time expiry, got {other:?}"),
    }
    assert_eq!(busy.wait().unwrap(), affine(1.0, 2));
    for (i, p) in backlog.into_iter().enumerate() {
        assert_eq!(p.wait().unwrap(), affine(3.0 + i as f32, 2));
    }
    let stats = server.shutdown();
    assert!(stats.accounted());
    assert_eq!((stats.shed, stats.shed_admission), (1, 1));
}
