//! `serve::` contract tests (DESIGN.md §Serving):
//!
//! 1. **Frozen parity** — `FrozenModel::from_checkpoint` logits are
//!    bit-identical to `Session::eval_logits` on mlp/alexnet checkpoints
//!    in Float32 and Static(8) modes (the int8 serving path runs integer
//!    GEMMs, yet lands on the same bits — the exactness argument in the
//!    `serve::frozen` module docs). Wider/BN-heavy models agree to float
//!    rounding.
//! 2. **Batching server** — responses are never mis-paired under
//!    concurrent pipelined submitters, backpressure blocks rather than
//!    drops, shutdown answers everything accepted, and malformed inputs
//!    are rejected.

use std::sync::Arc;

use apt::data::SynthImages;
use apt::kernels::Engine;
use apt::nn::{models, QuantMode};
use apt::serve::{FrozenModel, InferenceServer, ServeConfig};
use apt::tensor::Tensor;
use apt::train::SessionBuilder;

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_serve_ckpt_{tag}_{}.txt", std::process::id()))
}

/// Builder-default eval batch: the stream `Session::eval` reads.
fn eval_batch(n: usize) -> (Tensor, Vec<usize>) {
    let data = SynthImages::new(
        1000, // builder default: seed 0 + 1000
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    data.eval_set(999, n)
}

/// Train briefly, checkpoint, and return (session logits on a fixed eval
/// batch, frozen-model logits on the same batch, frozen model).
fn session_vs_frozen(
    model: &str,
    mode: QuantMode,
    iters: u64,
) -> (Tensor, Tensor, FrozenModel) {
    let path = ckpt_path(&format!("{model}_{}", mode.label()));
    let mut s = SessionBuilder::classifier(model).mode(mode).lr(0.01).build();
    s.run(iters).unwrap();
    s.save_checkpoint(&path).unwrap();

    // Reload into a fresh session (the same rebuild path a deployment
    // would use) and evaluate.
    let mut s2 = SessionBuilder::classifier(model).mode(mode).lr(0.01).build();
    s2.load_checkpoint(&path).unwrap();
    let (ex, _) = eval_batch(64);
    let want = s2.eval_logits(&ex);

    let frozen = FrozenModel::from_checkpoint(&path, model, mode).unwrap();
    let got = frozen.forward(&ex, apt::kernels::global());
    let _ = std::fs::remove_file(&path);
    (want, got, frozen)
}

fn assert_bits_equal(want: &Tensor, got: &Tensor, tag: &str) {
    assert_eq!(want.shape, got.shape, "{tag}: shape");
    for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag}: logit {i} diverged ({a} vs {b})"
        );
    }
}

fn max_rel_err(want: &Tensor, got: &Tensor) -> f32 {
    let scale = want.max_abs().max(1e-12);
    want.data
        .iter()
        .zip(&got.data)
        .map(|(a, b)| (a - b).abs() / scale)
        .fold(0.0f32, f32::max)
}

#[test]
fn frozen_bit_exact_f32_mlp_alexnet() {
    for model in ["mlp", "alexnet"] {
        let (want, got, frozen) = session_vs_frozen(model, QuantMode::Float32, 25);
        assert_eq!(frozen.precision(), "f32");
        assert_bits_equal(&want, &got, &format!("{model}-f32"));
    }
}

#[test]
fn frozen_bit_exact_int8_mlp_alexnet() {
    // The serving path runs i8 codes through the integer GEMM + one
    // rescale; with 8-bit schemes and k ≤ 1024 every sum is exact in both
    // paths, so this asserts *bit* equality, not closeness.
    for model in ["mlp", "alexnet"] {
        let (want, got, frozen) = session_vs_frozen(model, QuantMode::Static(8), 25);
        assert_eq!(frozen.precision(), "int8");
        assert_bits_equal(&want, &got, &format!("{model}-int8"));
    }
}

#[test]
fn frozen_close_on_wider_and_bn_models() {
    // int16: the session's fake-quant reference accumulates >24-bit
    // products in f32, so the (exact) integer path differs in float
    // rounding only.
    let (want, got, frozen) = session_vs_frozen("mlp", QuantMode::Static(16), 25);
    assert_eq!(frozen.precision(), "int16");
    let e = max_rel_err(&want, &got);
    assert!(e < 1e-3, "mlp-int16 rel err {e}");

    // BN/residual/inception/depthwise model families through the frozen
    // stack-op path (folded BN running stats, branch merge, add-back).
    for (model, mode) in [
        ("resnet", QuantMode::Float32),
        ("resnet", QuantMode::Static(8)),
        ("mobilenet", QuantMode::Static(8)),
        ("inception", QuantMode::Static(8)),
        ("vgg", QuantMode::Static(8)),
    ] {
        let (want, got, _) = session_vs_frozen(model, mode, 12);
        let e = max_rel_err(&want, &got);
        assert!(e < 1e-4, "{model}-{}: rel err {e}", mode.label());
    }
}

#[test]
fn frozen_from_live_net_matches_checkpoint_route() {
    let path = ckpt_path("live");
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Static(8)).build();
    s.run(15).unwrap();
    s.save_checkpoint(&path).unwrap();
    let via_ckpt = FrozenModel::from_checkpoint(&path, "mlp", QuantMode::Static(8)).unwrap();
    let via_net = FrozenModel::freeze("mlp-int8", s.net()).unwrap();
    let (ex, _) = eval_batch(16);
    let eng = Engine::serial();
    assert_bits_equal(&via_net.forward(&ex, &eng), &via_ckpt.forward(&ex, &eng), "live-vs-ckpt");
    let _ = std::fs::remove_file(&path);
}

fn quick_frozen_mlp() -> FrozenModel {
    let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Static(8)).build();
    s.run(10).unwrap();
    FrozenModel::freeze("mlp-int8", s.net()).unwrap()
}

#[test]
fn server_pairs_responses_under_concurrent_submitters() {
    let frozen = Arc::new(quick_frozen_mlp());
    let eng = Arc::new(Engine::serial());
    let cfg = ServeConfig { max_batch: 4, max_wait_us: 2_000, queue_cap: 64, workers: 2 };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::clone(&eng), cfg);

    let clients = 4usize;
    let per_client = 10usize;
    let mut data = SynthImages::new(
        7,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    let d = frozen.input_len();
    let (xs, _) = data.batch(clients * per_client);

    std::thread::scope(|scope| {
        for c in 0..clients {
            let server = &server;
            let frozen = &frozen;
            let eng = &eng;
            let xs = &xs;
            scope.spawn(move || {
                // Pipelined: submit the whole slice, then resolve in order;
                // every response must be the logits of *its own* input
                // (batched rows are computed independently, so single-
                // sample forward is the exact oracle).
                let mut pendings = Vec::new();
                for i in 0..per_client {
                    let idx = c * per_client + i;
                    pendings.push((idx, server.submit(xs.data[idx * d..(idx + 1) * d].to_vec()).unwrap()));
                }
                for (idx, p) in pendings {
                    let got = p.wait().unwrap();
                    let want = frozen.forward_one(&xs.data[idx * d..(idx + 1) * d], eng);
                    assert_eq!(got.len(), want.len());
                    for (a, b) in got.iter().zip(&want) {
                        assert_eq!(a.to_bits(), b.to_bits(), "request {idx} got another sample's logits");
                    }
                }
            });
        }
    });

    let stats = server.shutdown();
    assert_eq!(stats.accepted, (clients * per_client) as u64);
    assert_eq!(stats.served, (clients * per_client) as u64);
    assert!(stats.batches <= stats.served, "batches {} > served {}", stats.batches, stats.served);
    assert!(stats.mean_batch() >= 1.0);
}

#[test]
fn server_backpressure_bounded_queue_never_drops() {
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    // Tiny queue, one worker: concurrent blocking submitters must ride the
    // backpressure seam — block while full, never drop, never deadlock —
    // and the queue_cap < max_batch clamp must flush full queues instead
    // of waiting out the deadline (fill target = min(max_batch, queue_cap)).
    let cfg = ServeConfig { max_batch: 8, max_wait_us: 50_000, queue_cap: 2, workers: 1 };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg);
    let (threads, per) = (6usize, 8usize);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let server = &server;
            scope.spawn(move || {
                for _ in 0..per {
                    server.submit(vec![0.4; d]).unwrap().wait().unwrap();
                }
            });
        }
    });
    let stats = server.shutdown();
    assert_eq!(stats.accepted, (threads * per) as u64);
    assert_eq!(stats.served, (threads * per) as u64);
}

#[test]
fn try_submit_reports_full_queue_and_answers_all_accepted() {
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    // One worker, per-request batches, cap 2: a burst far faster than the
    // worker drains must hit the bounded-queue error on some submissions;
    // every accepted one must still be answered.
    let cfg = ServeConfig { max_batch: 1, max_wait_us: 0, queue_cap: 2, workers: 1 };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg);
    let burst = 200usize;
    let mut pendings = Vec::new();
    let mut full_errors = 0usize;
    for _ in 0..burst {
        match server.try_submit(vec![0.3; d]) {
            Ok(p) => pendings.push(p),
            Err(e) => {
                assert!(e.to_string().contains("full"), "unexpected error: {e}");
                full_errors += 1;
            }
        }
    }
    let accepted = pendings.len();
    assert_eq!(accepted + full_errors, burst);
    for p in pendings {
        p.wait().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.accepted, accepted as u64);
    assert_eq!(stats.served, accepted as u64);
    // A µs-scale burst against ms-scale forwards must engage the bound.
    assert!(full_errors > 0, "bounded queue never filled under a {burst}-deep burst");
}

#[test]
fn server_shutdown_answers_queued_requests() {
    let frozen = Arc::new(quick_frozen_mlp());
    let d = frozen.input_len();
    let cfg = ServeConfig { max_batch: 4, max_wait_us: 200_000, queue_cap: 64, workers: 1 };
    let server = InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), cfg);
    let pendings: Vec<_> = (0..9).map(|_| server.submit(vec![0.5; d]).unwrap()).collect();
    let stats = server.shutdown(); // close + drain + join
    assert_eq!(stats.served, 9);
    for p in pendings {
        assert_eq!(p.wait().unwrap().len(), models::CLASSES);
    }
}

#[test]
fn server_rejects_wrong_input_width() {
    let frozen = Arc::new(quick_frozen_mlp());
    let server =
        InferenceServer::start(Arc::clone(&frozen), Arc::new(Engine::serial()), ServeConfig::default());
    assert!(server.submit(vec![0.0; 3]).is_err());
    assert!(server.try_submit(vec![]).is_err());
}

#[test]
fn freeze_infers_geometry_and_labels() {
    let frozen = quick_frozen_mlp();
    assert_eq!(frozen.input_len(), models::input_len());
    assert_eq!(frozen.label(), "mlp-int8");
    let logits = frozen.forward_one(&vec![0.0; frozen.input_len()], &Engine::serial());
    assert_eq!(logits.len(), models::CLASSES);
}
