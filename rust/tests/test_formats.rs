//! Format-family contract tests (DESIGN.md §Formats) — the gate for the
//! Scheme → Format generalization:
//!
//! 1. **Codec bounds** — every family's fake-quant stays within its
//!    resolution/half-ulp error envelope on random data, and the scaled-fp8
//!    byte codec (`codes_f8`/`decode_f8`, the stash/wire payload encoding)
//!    lands on exactly the same values as `Format::fake_quant`.
//! 2. **Monotonicity** — fake-quant never reorders inputs in any family
//!    (a saturating rounding codec must be a monotone step function).
//! 3. **Int4 packing** — nibble pack/unpack is exact for every code in
//!    [−8, 7] at every length parity.
//! 4. **Per-channel ≡ per-tensor at equal scales** — per-channel scales are
//!    a pure refinement: when all channels share one range the per-channel
//!    kernels are bit-identical to the per-tensor path.
//! 5. **Fixed-point pin** — a `FormatFamily::FixedPoint` config takes
//!    exactly the pre-format code paths (bit-identical losses).
//! 6. **Checkpoint v4** — a per-channel e4m3 session round-trips
//!    bit-identically through the v4 format, and the committed v1/v2/v3
//!    fixtures still load under the v4 reader.
//! 7. **Int4 weight-only serving** — ≤ 0.55× the int8 weight bytes with
//!    ≥ 99% top-1 agreement on the synthetic eval stream.

use apt::apt::AptConfig;
use apt::compiler::CompileOptions;
use apt::data::SynthImages;
use apt::fixedpoint::{
    pack_nibbles, quantize, unpack_nibbles, Format, FormatFamily, MinifloatKind, Scheme,
};
use apt::kernels::Engine;
use apt::nn::{models, QuantMode};
use apt::serve::FrozenModel;
use apt::tensor::Tensor;
use apt::train::checkpoint::Checkpoint;
use apt::train::SessionBuilder;
use apt::util::proptest::check;

const FAMILIES: [FormatFamily; 4] = [
    FormatFamily::FixedPoint,
    FormatFamily::E4M3,
    FormatFamily::E5M2,
    FormatFamily::Int4,
];

// ------------------------------------------------------------ codec bounds

/// Worst-case |x − fq(x)| for a format on an in-range input: half a
/// resolution step for the fixed-point families, a half-ulp of relative
/// error plus one subnormal quantum for the minifloats.
fn error_bound(fmt: Format, x: f32) -> f32 {
    match fmt {
        Format::FixedPoint(_) | Format::Int4 { .. } => fmt.resolution() / 2.0,
        Format::Minifloat { kind, .. } => {
            let (_, mbits, _) = kind.spec();
            x.abs() * (-(mbits as f32 + 1.0)).exp2() + fmt.resolution()
        }
    }
}

#[test]
fn prop_fake_quant_error_within_family_envelope() {
    check("format-error-envelope", 60, |g| {
        let family = *g.choose(&FAMILIES);
        let scale = g.f32_log(1e-4, 1e4);
        let xs = g.normal_vec(128, scale);
        let fmt = Format::for_range(family, quantize::max_abs(&xs), 8);
        for &x in &xs {
            let q = fmt.fake_quant(x);
            let e = (x - q).abs();
            let bound = error_bound(fmt, x) + 1e-12;
            assert!(e <= bound, "{family:?} x={x} q={q} err={e} bound={bound}");
        }
    });
}

#[test]
fn prop_f8_byte_codec_matches_fake_quant() {
    // The stash/wire byte path (encode to codes, decode later) must land on
    // exactly the values the in-place fake-quant produces — otherwise a
    // stashed activation and a live one would diverge.
    check("f8-codec-consistency", 40, |g| {
        let kind = *g.choose(&[MinifloatKind::E4M3, MinifloatKind::E5M2]);
        let xs = g.normal_vec(g.usize(1, 200), g.f32_log(1e-3, 1e3));
        let fmt = Format::for_range(kind.family(), quantize::max_abs(&xs), 8);
        let s = fmt.scale_exp();
        let mut codes = vec![0u8; xs.len()];
        quantize::codes_f8(&xs, &mut codes, kind, s);
        let mut back = vec![0f32; xs.len()];
        quantize::decode_f8(&codes, &mut back, kind, s);
        for (&x, &b) in xs.iter().zip(&back) {
            assert_eq!(
                b.to_bits(),
                fmt.fake_quant(x).to_bits(),
                "{} x={x}: codec {b} vs fake_quant {}",
                kind.label(),
                fmt.fake_quant(x)
            );
        }
    });
}

#[test]
fn prop_fake_quant_monotone_in_every_family() {
    check("format-monotone", 60, |g| {
        let family = *g.choose(&FAMILIES);
        let fmt = Format::for_range(family, g.f32_log(1e-2, 1e2), 8);
        let top = fmt.range_top();
        let mut a = g.f32(-2.0 * top, 2.0 * top);
        let mut b = g.f32(-2.0 * top, 2.0 * top);
        if a > b {
            std::mem::swap(&mut a, &mut b);
        }
        let (qa, qb) = (fmt.fake_quant(a), fmt.fake_quant(b));
        assert!(qa <= qb, "{family:?}: fq({a})={qa} > fq({b})={qb}");
    });
}

// ------------------------------------------------------------ int4 packing

#[test]
fn prop_nibble_pack_exact_for_all_codes_and_parities() {
    check("int4-pack-exact", 60, |g| {
        let len = g.usize(1, 101);
        let codes: Vec<i8> = (0..len).map(|_| g.int(-8, 7) as i8).collect();
        let packed = pack_nibbles(&codes);
        assert_eq!(packed.len(), len.div_ceil(2));
        let mut back = vec![0i8; len];
        unpack_nibbles(&packed, &mut back);
        assert_eq!(back, codes);
    });
}

// ---------------------------------------- per-channel vs per-tensor scales

#[test]
fn prop_per_channel_equals_per_tensor_when_scales_agree() {
    // Replicated rows ⇒ every channel sees the same range ⇒ the per-channel
    // scale vector is constant and the refinement must vanish bitwise.
    check("per-channel-identity", 40, |g| {
        let family = *g.choose(&FAMILIES);
        let bits = 8u8;
        let (rows, cols) = (g.usize(2, 8), g.usize(1, 32));
        let row = g.normal_vec(cols, g.f32_log(1e-2, 1e2));
        let w: Vec<f32> = (0..rows).flat_map(|_| row.iter().copied()).collect();

        let scales = quantize::channel_scales_rows(&w, rows, cols, family, bits);
        assert!(scales.windows(2).all(|p| p[0] == p[1]), "{family:?}: {scales:?}");
        let fmt = Format::for_range(family, quantize::max_abs(&w), bits);
        assert_eq!(scales[0], fmt.scale_exp(), "{family:?}");

        let mut pc = w.clone();
        let st_pc = quantize::fake_quant_per_channel_rows(&mut pc, rows, cols, family, bits, &scales);
        let mut pt = w.clone();
        let st_pt = quantize::fake_quant_stats_inplace_fmt(&mut pt, fmt);
        for (i, (a, b)) in pc.iter().zip(&pt).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{family:?} elem {i}: {a} vs {b}");
        }
        // fused stats agree too (tolerance: the two kernels accumulate the
        // f64 sums in different association orders)
        let tol = 1e-9 * st_pt.sum_abs_q.abs().max(1.0);
        assert!(
            (st_pc.sum_abs_q - st_pt.sum_abs_q).abs() <= tol,
            "{family:?}: fused stats diverged: {} vs {}",
            st_pc.sum_abs_q,
            st_pt.sum_abs_q
        );
    });
}

// ------------------------------------------------------- fixed-point pins

#[test]
fn fixed_point_family_config_trains_bit_identically_to_default() {
    // `for_family(FixedPoint)` must be the do-nothing spelling of the
    // default config: same RNG draws, same schemes, same losses to the bit.
    let run = |cfg: AptConfig| {
        let mut s = SessionBuilder::classifier("mlp").mode(QuantMode::Adaptive(cfg)).build();
        s.run(10).unwrap();
        s.losses().to_vec()
    };
    let mut base = AptConfig::default();
    base.init_phase_iters = 2;
    let mut fam = AptConfig::for_family(FormatFamily::FixedPoint);
    fam.init_phase_iters = 2;
    let (a, b) = (run(base), run(fam));
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "loss {i} diverged: {x} vs {y}");
    }
}

// ---------------------------------------------------------- checkpoint v4

fn ckpt_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apt_formats_ckpt_{tag}_{}.txt", std::process::id()))
}

fn fixture(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

#[test]
fn v4_roundtrips_a_per_channel_e4m3_session_bit_identically() {
    let mut cfg = AptConfig::for_family(FormatFamily::E4M3);
    cfg.init_phase_iters = 2;
    cfg.per_channel_weights = true;
    let build = || {
        SessionBuilder::classifier("mlp")
            .mode(QuantMode::Adaptive(cfg))
            .build()
    };
    let path = ckpt_path("v4_e4m3_pc");
    let mut a = build();
    a.run(8).unwrap();
    a.save_checkpoint(&path).unwrap();

    // the artifact is v4 and records both format tags and channel scales
    let text = std::fs::read_to_string(&path).unwrap();
    let header = text.lines().next().unwrap();
    assert!(header.ends_with(" v4"), "unexpected header {header:?}");
    assert!(text.contains("e4m3"), "no format-family tags in the file");
    assert!(text.contains("pcs"), "no per-channel scale section");
    assert!(Checkpoint::read(&path).is_ok());

    let mut b = build();
    b.load_checkpoint(&path).unwrap();
    assert_eq!(b.iters_done(), 8);
    a.run(6).unwrap();
    b.run(6).unwrap();
    assert_eq!(a.losses(), b.losses(), "restored e4m3 per-channel run diverged");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn v4_reader_rejects_family_mismatch() {
    // an e4m3 checkpoint must not restore into a fixed-point session
    let mut cfg = AptConfig::for_family(FormatFamily::E5M2);
    cfg.init_phase_iters = 2;
    let path = ckpt_path("v4_mismatch");
    let mut a = SessionBuilder::classifier("mlp").mode(QuantMode::Adaptive(cfg)).build();
    a.run(4).unwrap();
    a.save_checkpoint(&path).unwrap();

    let mut fixed = AptConfig::default();
    fixed.init_phase_iters = 2;
    let mut b = SessionBuilder::classifier("mlp").mode(QuantMode::Adaptive(fixed)).build();
    let err = b.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("family"), "unexpected error: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn v1_v2_v3_fixtures_still_load_under_the_v4_reader() {
    for (name, iters) in [
        ("host_f32_v1.ckpt", 3),
        ("host_int8_v2.ckpt", 3),
        ("parallel_topk_v3.ckpt", 2),
    ] {
        let ck = Checkpoint::read(&fixture(name)).unwrap_or_else(|e| {
            panic!("{name} no longer parses under the v4 reader: {e:#}")
        });
        assert_eq!(ck.iters_done(), iters, "{name}");
    }
}

// ------------------------------------------------- int4 weight-only serve

fn eval_batch(n: usize) -> Tensor {
    let data = SynthImages::new(
        1000,
        models::CLASSES,
        models::IN_C,
        models::IN_H,
        models::IN_W,
        0.5,
    );
    data.eval_set(999, n).0
}

fn top1(logits: &Tensor) -> Vec<usize> {
    let (n, c) = (logits.shape[0], logits.shape[1]);
    (0..n)
        .map(|i| {
            logits.data[i * c..(i + 1) * c]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j)
                .unwrap()
        })
        .collect()
}

#[test]
fn int4_weight_only_alexnet_halves_weight_bytes_and_keeps_top1() {
    let mut s = SessionBuilder::classifier("alexnet").mode(QuantMode::Static(8)).lr(0.01).build();
    s.run(60).unwrap();
    let i8m = FrozenModel::freeze("alexnet-int8".to_string(), s.net()).unwrap();
    let opts = CompileOptions {
        weight_format: Some(FormatFamily::Int4),
        ..CompileOptions::default()
    };
    let i4m = FrozenModel::freeze_with("alexnet-int4w".to_string(), s.net(), &opts).unwrap();
    assert_eq!(i4m.precision(), "int4w");

    let (w8, w4) = (i8m.compile_report().weight_bytes, i4m.compile_report().weight_bytes);
    assert!(w8 > 0 && w4 > 0, "weight byte accounting missing: int8 {w8}, int4 {w4}");
    assert!(
        w4 * 100 <= w8 * 55,
        "int4 weight-only must be ≤ 0.55× the int8 weight bytes: {w4} vs {w8}"
    );

    let ex = eval_batch(256);
    let eng = Engine::serial();
    let p8 = top1(&i8m.forward(&ex, &eng));
    let p4 = top1(&i4m.forward(&ex, &eng));
    let agree = p8.iter().zip(&p4).filter(|(a, b)| a == b).count();
    assert!(
        agree * 100 >= p8.len() * 99,
        "int4w top-1 agreement too low: {agree}/{}",
        p8.len()
    );
}
