"""L2 correctness: qlinear implements Algorithm 1; train steps learn.

The key contract is that ``jax.grad`` through :func:`model.qlinear` produces
exactly the paper's three quantized products (FPROP/BPROP/WTGRAD) and that
the dY QEM statistics ride out as the gtap cotangent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, scale, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(np.float32))


def qp_row(x, w, g, bits=(8, 8, 16)):
    vals = []
    for t, b in zip((x, w, g), bits):
        vals += list(ref.scheme_params(float(jnp.max(jnp.abs(t))), b))
    return jnp.asarray(vals, jnp.float32)


class TestQLinear:
    def test_forward_is_quantized_product(self):
        x, w = rand((16, 8), 1.0, 0), rand((8, 4), 0.5, 1)
        g = jnp.ones((16, 4), jnp.float32)
        qp = qp_row(x, w, g)
        y = model.qlinear(x, w, qp, jnp.zeros((3, 6)))
        xh = ref.fake_quant(x, qp[0], qp[1], qp[2])
        wh = ref.fake_quant(w, qp[3], qp[4], qp[5])
        np.testing.assert_allclose(np.asarray(y), np.asarray(xh @ wh), rtol=1e-6)

    def test_backward_matches_algorithm1(self):
        """dX = g_hat @ W_hat^T and dW = X_hat^T @ g_hat, exactly."""
        x, w = rand((16, 8), 1.0, 2), rand((8, 4), 0.5, 3)
        g = rand((16, 4), 2.0, 4)
        qp = qp_row(x, w, g)

        def f(x_, w_):
            return jnp.sum(model.qlinear(x_, w_, qp, jnp.zeros((3, 6))) * g)

        dx, dw = jax.grad(f, argnums=(0, 1))(x, w)
        xh = ref.fake_quant(x, qp[0], qp[1], qp[2])
        wh = ref.fake_quant(w, qp[3], qp[4], qp[5])
        gh = ref.fake_quant(g, qp[6], qp[7], qp[8])
        np.testing.assert_allclose(np.asarray(dx), np.asarray(gh @ wh.T), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(xh.T @ gh), rtol=1e-5, atol=1e-6)

    def test_gtap_cotangent_carries_gradient_stats(self):
        x, w = rand((16, 8), 1.0, 5), rand((8, 4), 0.5, 6)
        g = rand((16, 4), 2.0, 7)
        qp = qp_row(x, w, g)

        def f(x_, gtap):
            return jnp.sum(model.qlinear(x_, w, qp, gtap) * g)

        stats = jax.grad(f, argnums=1)(x, jnp.zeros((3, 6)))
        # row 0: W stats, row 1: X stats, row 2: dY stats
        for row, t_ in ((0, w), (1, x), (2, g)):
            pr = qp[3:6] if row == 0 else (qp[0:3] if row == 1 else qp[6:9])
            s, sq, mx = ref.qem_stats(t_, pr[0], pr[1], pr[2])
            np.testing.assert_allclose(float(stats[row, 0]), float(s), rtol=1e-5)
            np.testing.assert_allclose(float(stats[row, 1]), float(mx), rtol=1e-6)
            np.testing.assert_allclose(float(stats[row, 2]), float(sq), rtol=1e-5)

    def test_high_bitwidth_approaches_float_grads(self):
        x, w = rand((8, 8), 1.0, 8), rand((8, 8), 0.5, 9)
        qp = qp_row(x, w, jnp.ones((8, 8)), bits=(24, 24, 24))

        def fq(x_, w_):
            return jnp.sum(jnp.tanh(model.qlinear(x_, w_, qp, jnp.zeros((3, 6)))))

        def ff(x_, w_):
            return jnp.sum(jnp.tanh(x_ @ w_))

        dq = jax.grad(fq, argnums=(0, 1))(x, w)
        df = jax.grad(ff, argnums=(0, 1))(x, w)
        for a, b in zip(dq, df):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2, atol=1e-3)


class TestMLP:
    def _data(self, batch=32, seed=0):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((batch, model.MLP_DIMS[0])).astype(np.float32)
        y = rng.integers(0, model.MLP_DIMS[-1], batch).astype(np.int32)
        return jnp.asarray(x), jnp.asarray(y)

    def test_train_step_shapes_and_loss_finite(self):
        params = model.mlp_init(jax.random.PRNGKey(0))
        n_q = model.mlp_n_q()
        x, y = self._data()
        qp = model.default_qparams(n_q)
        gt = jnp.zeros((n_q, 3, model.N_STATS))
        p2, loss, wst, xst, gst = model.mlp_train_step(params, x, y, qp, gt, 0.05)
        assert np.isfinite(float(loss))
        assert wst.shape == (n_q, model.N_STATS)
        assert gst.shape == (n_q, model.N_STATS)
        for (w2, b2), (w1, b1) in zip(p2, params):
            assert w2.shape == w1.shape and b2.shape == b1.shape
            assert not np.allclose(np.asarray(w2), np.asarray(w1))  # it moved

    def test_loss_decreases_with_int8_fwd_int16_bwd(self):
        """The paper's configuration must learn a separable toy problem."""
        step = jax.jit(model.mlp_train_step)
        params = model.mlp_init(jax.random.PRNGKey(1))
        n_q = model.mlp_n_q()
        gt = jnp.zeros((n_q, 3, model.N_STATS))
        rng = np.random.default_rng(0)
        # two gaussian blobs per class over 10 classes
        centers = rng.standard_normal((10, model.MLP_DIMS[0])).astype(np.float32) * 2
        losses = []
        for i in range(30):
            y = rng.integers(0, 10, 32).astype(np.int32)
            x = centers[y] + rng.standard_normal((32, model.MLP_DIMS[0])).astype(np.float32) * 0.3
            # refresh qparams from live ranges like the Rust controller does
            qp = model.default_qparams(n_q, bits=(8, 8, 16), assumed_range=6.0)
            params, loss, *_ = step(params, jnp.asarray(x), jnp.asarray(y), qp, gt, 0.05)
            losses.append(float(loss))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) * 0.7, losses

    def test_eval_runs(self):
        params = model.mlp_init(jax.random.PRNGKey(2))
        n_q = model.mlp_n_q()
        x, y = self._data()
        acc, loss = model.mlp_eval(params, x, y, model.default_qparams(n_q), jnp.zeros((n_q, 3, 6)))
        assert 0.0 <= float(acc) <= 1.0 and np.isfinite(float(loss))


class TestTransformer:
    CFG = model.tfm_config(vocab=32, seq=16, d_model=32, n_heads=2, n_layers=1)

    def test_forward_shapes(self):
        cfg = self.CFG
        p = model.tfm_init(jax.random.PRNGKey(0), cfg)
        n_q = model.tfm_n_q(cfg)
        toks = jnp.zeros((2, cfg["seq"]), jnp.int32)
        qp = model.default_qparams(n_q)
        logits = model.tfm_forward(p, toks, cfg, qp, jnp.zeros((n_q, 3, 6)))
        assert logits.shape == (2, cfg["seq"], cfg["vocab"])

    def test_train_step_learns_copy_task(self):
        cfg = self.CFG
        p = model.tfm_init(jax.random.PRNGKey(1), cfg)
        m = jax.tree_util.tree_map(jnp.zeros_like, p)
        v = jax.tree_util.tree_map(jnp.zeros_like, p)
        n_q = model.tfm_n_q(cfg)
        qp = model.default_qparams(n_q, bits=(8, 8, 16), assumed_range=4.0)
        gt = jnp.zeros((n_q, 3, model.N_STATS))
        step = jax.jit(lambda p, m, v, t, tg, s: model.tfm_train_step(
            p, m, v, t, tg, cfg, qp, gt, 3e-3, s))
        rng = np.random.default_rng(0)
        losses = []
        for i in range(25):
            # predictable sequence: token t+1 = token t + 1 (mod vocab)
            start = rng.integers(0, cfg["vocab"], (4, 1))
            seq = (start + np.arange(cfg["seq"] + 1)[None, :]) % cfg["vocab"]
            toks = jnp.asarray(seq[:, :-1].astype(np.int32))
            tgts = jnp.asarray(seq[:, 1:].astype(np.int32))
            p, m, v, loss, *_ = step(p, m, v, toks, tgts, jnp.float32(i + 1))
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.6, losses

    def test_n_q_counts_every_projection(self):
        cfg = self.CFG
        assert model.tfm_n_q(cfg) == cfg["n_layers"] * model.TFM_Q_PER_BLOCK + 1
