"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, value scales, and bit-widths; every property the
Rust `fixedpoint` module relies on is pinned here first.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import qmatmul as kmm
from compile.kernels import quantize as kq
from compile.kernels import ref
from compile.kernels import stats as ks

jax.config.update("jax_platform_name", "cpu")

SETTINGS = settings(max_examples=25, deadline=None)


def rand(shape, scale, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# --------------------------------------------------------------------------
# scheme_params / resolution_exponent
# --------------------------------------------------------------------------


@given(
    max_abs=st.floats(1e-6, 1e6),
    bits=st.sampled_from([8, 12, 16, 24]),
)
@SETTINGS
def test_scheme_covers_range(max_abs, bits):
    """The paper's scale: r*qmax must reach max_abs, and not overshoot 2x."""
    r, qmin, qmax = ref.scheme_params(max_abs, bits)
    assert r * qmax >= max_abs * (1 - 1e-6)
    # ceil() overshoots by at most one power of two
    assert r * qmax < 2 * max_abs * (1 + 1e-6) + r


def test_scheme_zero_range():
    r, qmin, qmax = ref.scheme_params(0.0, 8)
    assert r > 0 and qmin == -128 and qmax == 127


@given(bits=st.sampled_from([8, 16, 24]))
@SETTINGS
def test_code_bounds(bits):
    _, qmin, qmax = ref.scheme_params(1.0, bits)
    assert qmin == -(2 ** (bits - 1))
    assert qmax == 2 ** (bits - 1) - 1


# --------------------------------------------------------------------------
# fake_quant kernel vs oracle
# --------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 3, 64, 200, 300]),
    n=st.sampled_from([1, 5, 64, 128]),
    scale=st.sampled_from([1e-4, 1.0, 100.0]),
    bits=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
@SETTINGS
def test_fake_quant_matches_ref(m, n, scale, bits, seed):
    x = rand((m, n), scale, seed)
    r, qmin, qmax = ref.scheme_params(float(np.abs(x).max()), bits)
    got = kq.fake_quant(jnp.asarray(x), r, qmin, qmax)
    want = ref.fake_quant(jnp.asarray(x), r, qmin, qmax)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fake_quant_idempotent():
    x = rand((64, 64), 3.0, 0)
    r, qmin, qmax = ref.scheme_params(float(np.abs(x).max()), 8)
    q1 = np.asarray(kq.fake_quant(jnp.asarray(x), r, qmin, qmax))
    q2 = np.asarray(kq.fake_quant(jnp.asarray(q1), r, qmin, qmax))
    np.testing.assert_array_equal(q1, q2)


def test_fake_quant_saturates():
    x = jnp.asarray([[1000.0, -1000.0]], jnp.float32)
    r, qmin, qmax = 1.0, -128.0, 127.0
    out = np.asarray(kq.fake_quant(x, r, qmin, qmax))
    assert out[0, 0] == 127.0 and out[0, 1] == -128.0


@given(bits=st.sampled_from([8, 16, 24]), seed=st.integers(0, 2**16))
@SETTINGS
def test_quant_error_bounded_by_half_resolution(bits, seed):
    """|x - x_hat| <= r/2 for in-range data — the fixed-point contract."""
    x = rand((32, 32), 1.0, seed)
    r, qmin, qmax = ref.scheme_params(float(np.abs(x).max()), bits)
    xq = np.asarray(ref.fake_quant(jnp.asarray(x), r, qmin, qmax))
    assert np.max(np.abs(x - xq)) <= r / 2 + 1e-9


# --------------------------------------------------------------------------
# stats kernel vs oracle
# --------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 7, 64, 300]),
    n=st.sampled_from([1, 33, 64]),
    scale=st.sampled_from([1e-3, 1.0, 50.0]),
    seed=st.integers(0, 2**16),
)
@SETTINGS
def test_stats_matches_ref(m, n, scale, seed):
    x = rand((m, n), scale, seed)
    xj = jnp.asarray(x)
    r, qmin, qmax = ref.scheme_params(float(np.abs(x).max()), 8)
    got = np.asarray(ks.qem_stats(xj, r, qmin, qmax))
    s, sq, mx = (np.asarray(v) for v in ref.qem_stats(xj, r, qmin, qmax))
    np.testing.assert_allclose(got[0], s, rtol=1e-5)
    np.testing.assert_allclose(got[1], mx, rtol=1e-6)
    np.testing.assert_allclose(got[2], sq, rtol=1e-5)
    # candidate sums: recompute with the oracle at each width
    rng = float(np.abs(x).max())
    for idx, bits in zip((3, 4, 5), ks.CANDIDATE_BITS):
        rc, lo, hi = ref.scheme_params(rng, bits)
        want = np.sum(np.abs(ref.np_fake_quant(x, rc, lo, hi)))
        np.testing.assert_allclose(got[idx], want, rtol=1e-5)


def test_stats_diff_decreases_with_bits():
    """QEM Diff must be monotone non-increasing in bit-width (paper Obs. 3)."""
    x = rand((256, 64), 1.0, 7)
    s = float(np.sum(np.abs(x)))
    diffs = []
    for bits in (8, 16, 24):
        r, lo, hi = ref.scheme_params(float(np.abs(x).max()), bits)
        sq = float(np.sum(np.abs(ref.np_fake_quant(x, r, lo, hi))))
        diffs.append(ref.qem_diff(s, sq))
    assert diffs[0] >= diffs[1] >= diffs[2]
    assert diffs[2] < 1e-3


def test_qem_diff_zero_for_exact():
    assert ref.qem_diff(10.0, 10.0) == 0.0
    assert ref.qem_diff(0.0, 0.0) == 0.0


# --------------------------------------------------------------------------
# qmatmul kernel vs oracle + integer-exactness property
# --------------------------------------------------------------------------


@given(
    m=st.sampled_from([1, 16, 64, 130]),
    k=st.sampled_from([1, 32, 64]),
    n=st.sampled_from([1, 16, 64, 129]),
    seed=st.integers(0, 2**16),
)
@SETTINGS
def test_qmatmul_matches_ref(m, k, n, seed):
    x = rand((m, k), 1.0, seed)
    w = rand((k, n), 0.2, seed + 1)
    rx, lxo, hxo = ref.scheme_params(float(np.abs(x).max()), 8)
    rw, lwo, hwo = ref.scheme_params(float(np.abs(w).max()), 8)
    got = np.asarray(kmm.qmatmul(jnp.asarray(x), jnp.asarray(w), rx, lxo, hxo, rw, lwo, hwo))
    want = np.asarray(ref.qmatmul(jnp.asarray(x), jnp.asarray(w), rx, lxo, hxo, rw, lwo, hwo))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_qmatmul_equals_fakequant_matmul():
    """r1*r2*(I1@I2) must be bit-equal to x_hat @ w_hat (paper Eq. 12)."""
    x = rand((64, 64), 2.0, 3)
    w = rand((64, 64), 0.5, 4)
    rx, lx, hx = ref.scheme_params(float(np.abs(x).max()), 8)
    rw, lw, hw = ref.scheme_params(float(np.abs(w).max()), 8)
    via_codes = np.asarray(ref.qmatmul(jnp.asarray(x), jnp.asarray(w), rx, lx, hx, rw, lw, hw))
    xh = ref.np_fake_quant(x, rx, lx, hx)
    wh = ref.np_fake_quant(w, rw, lw, hw)
    np.testing.assert_allclose(via_codes, xh @ wh, rtol=1e-6, atol=1e-6)


def test_qmatmul_high_bits_converges_to_f32():
    x = rand((32, 32), 1.0, 5)
    w = rand((32, 32), 1.0, 6)
    rx, lx, hx = ref.scheme_params(float(np.abs(x).max()), 24)
    rw, lw, hw = ref.scheme_params(float(np.abs(w).max()), 24)
    got = np.asarray(ref.qmatmul(jnp.asarray(x), jnp.asarray(w), rx, lx, hx, rw, lw, hw))
    np.testing.assert_allclose(got, x @ w, rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------
# Appendix A property: m_x/m_xhat > 1 and grows with (b-a)^2 * (-k)
# --------------------------------------------------------------------------


def _mean_ratio(sigma, bits):
    rng = np.random.default_rng(0)
    x = np.abs(rng.normal(0.0, sigma, 200_000)).astype(np.float32)
    r, lo, hi = ref.scheme_params(float(x.max()), bits)
    xq = ref.np_fake_quant(x, r, lo, hi)
    return float(np.mean(x) / max(np.mean(xq), 1e-30))


def test_appendix_a_mean_ratio_above_one():
    # Coarse quantization of a half-Gaussian over-shrinks the mean (S3 >> S4
    # in the paper's Fig. 4): ratio > 1 and decreasing with bit-width.
    r8 = _mean_ratio(1.0, 6)
    r16 = _mean_ratio(1.0, 12)
    assert r8 > 1.0
    assert r8 > r16
    assert abs(r16 - 1.0) < abs(r8 - 1.0)


def test_vmem_budget():
    """The default qmatmul tiling must fit comfortably in 16 MiB VMEM."""
    assert kmm.vmem_bytes() <= 4 * 1024 * 1024
