"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (never ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also emits ``artifacts/manifest.txt`` describing each artifact's I/O so the
Rust runtime can marshal Literals without any Python at run time:

    artifact <name> <file>
    in <name> <dtype> <d0,d1,...|scalar>
    out <name> <dtype> <dims|scalar>

Usage:  python -m compile.aot --out-dir ../artifacts [--preset small|e2e]
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import quantize as kq
from .kernels import stats as ks
from .kernels import qmatmul as kmm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dims(s) -> str:
    return "scalar" if len(s.shape) == 0 else ",".join(str(d) for d in s.shape)


def _dt(s) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}[s.dtype]


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.lines: list[str] = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name, fn, in_named, out_named):
        """Lower fn(*inputs) -> tuple(outputs); record manifest entries."""
        specs = [s for _, s in in_named]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.lines.append(f"artifact {name} {fname}")
        for n, s in in_named:
            self.lines.append(f"in {n} {_dt(s)} {_dims(s)}")
        for n, s in out_named:
            self.lines.append(f"out {n} {_dt(s)} {_dims(s)}")
        print(f"  {fname}: {len(text)} chars, {len(in_named)} in / {len(out_named)} out")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.txt"), "w") as f:
            f.write("\n".join(self.lines) + "\n")


# --------------------------------------------------------------------------
# kernel-level artifacts (runtime unit tests + Rust-vs-oracle cross checks)
# --------------------------------------------------------------------------


def emit_kernel_artifacts(em: Emitter):
    m, n, k = 64, 64, 64

    def fq(x, params):
        return (kq.fake_quant_pallas(x, params),)

    em.emit(
        "quant_fake",
        fq,
        [("x", _spec((m, n))), ("params", _spec((3,)))],
        [("xq", _spec((m, n)))],
    )

    def st(x, params):
        return (ks.qem_stats_pallas(x, params),)

    em.emit(
        "qem_stats",
        st,
        [("x", _spec((m, n))), ("params", _spec((4,)))],
        [("stats", _spec((ks.N_STATS,)))],
    )

    def mm(x, w, params):
        return (kmm.qmatmul_pallas(x, w, params),)

    em.emit(
        "qmatmul",
        mm,
        [("x", _spec((m, k))), ("w", _spec((k, n))), ("params", _spec((6,)))],
        [("y", _spec((m, n)))],
    )


# --------------------------------------------------------------------------
# MLP train/eval artifacts
# --------------------------------------------------------------------------


def emit_mlp(em: Emitter, batch=32, dims=model.MLP_DIMS):
    n_q = model.mlp_n_q(dims)
    pshapes = []
    for i in range(len(dims) - 1):
        pshapes += [(f"w{i}", (dims[i], dims[i + 1])), (f"b{i}", (dims[i + 1],))]

    def unflatten(flat):
        return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(dims) - 1)]

    def step(*args):
        flat = args[: 2 * n_q]
        x, labels, qparams, lr = args[2 * n_q :]
        gtaps = jnp.zeros((n_q, 3, model.N_STATS), jnp.float32)
        new_params, loss, wst, xst, gst = model.mlp_train_step(
            unflatten(flat), x, labels, qparams, gtaps, lr
        )
        out = []
        for w, b in new_params:
            out += [w, b]
        return tuple(out) + (loss, wst, xst, gst)

    ins = [(n, _spec(s)) for n, s in pshapes] + [
        ("x", _spec((batch, dims[0]))),
        ("labels", _spec((batch,), jnp.int32)),
        ("qparams", _spec((n_q, model.QP_LEN))),
        ("lr", _spec(())),
    ]
    outs = (
        [(f"new_{n}", _spec(s)) for n, s in pshapes]
        + [("loss", _spec(()))]
        + [
            ("wstats", _spec((n_q, model.N_STATS))),
            ("xstats", _spec((n_q, model.N_STATS))),
            ("gstats", _spec((n_q, model.N_STATS))),
        ]
    )
    em.emit("mlp_train_step", step, ins, outs)

    def ev(*args):
        flat = args[: 2 * n_q]
        x, labels, qparams = args[2 * n_q :]
        gtaps = jnp.zeros((n_q, 3, model.N_STATS), jnp.float32)
        acc, loss = model.mlp_eval(unflatten(flat), x, labels, qparams, gtaps)
        return (acc, loss)

    em.emit(
        "mlp_eval",
        ev,
        [(n, _spec(s)) for n, s in pshapes]
        + [
            ("x", _spec((batch, dims[0]))),
            ("labels", _spec((batch,), jnp.int32)),
            ("qparams", _spec((n_q, model.QP_LEN))),
        ],
        [("acc", _spec(())), ("loss", _spec(()))],
    )


# --------------------------------------------------------------------------
# Transformer-LM train artifact (E2E driver)
# --------------------------------------------------------------------------


def emit_tfm(em: Emitter, cfg, batch):
    n_q = model.tfm_n_q(cfg)
    key = jax.random.PRNGKey(0)
    p0 = model.tfm_init(key, cfg)
    names = sorted(p0.keys())  # deterministic order shared with Rust
    shapes = {k: p0[k].shape for k in names}

    def pack(flat):
        return {k: v for k, v in zip(names, flat)}

    n = len(names)

    def step(*args):
        p = pack(args[0:n])
        m = pack(args[n : 2 * n])
        v = pack(args[2 * n : 3 * n])
        tokens, targets, qparams, lr, stepno = args[3 * n :]
        gtaps = jnp.zeros((n_q, 3, model.N_STATS), jnp.float32)
        p2, m2, v2, loss, wst, xst, gst = model.tfm_train_step(
            p, m, v, tokens, targets, cfg, qparams, gtaps, lr, stepno
        )
        out = [p2[k] for k in names] + [m2[k] for k in names] + [v2[k] for k in names]
        return tuple(out) + (loss, wst, xst, gst)

    b, s = batch, cfg["seq"]
    ins = (
        [(f"p_{k}", _spec(shapes[k])) for k in names]
        + [(f"m_{k}", _spec(shapes[k])) for k in names]
        + [(f"v_{k}", _spec(shapes[k])) for k in names]
        + [
            ("tokens", _spec((b, s), jnp.int32)),
            ("targets", _spec((b, s), jnp.int32)),
            ("qparams", _spec((n_q, model.QP_LEN))),
            ("lr", _spec(())),
            ("step", _spec(())),
        ]
    )
    outs = (
        [(f"new_p_{k}", _spec(shapes[k])) for k in names]
        + [(f"new_m_{k}", _spec(shapes[k])) for k in names]
        + [(f"new_v_{k}", _spec(shapes[k])) for k in names]
        + [
            ("loss", _spec(())),
            ("wstats", _spec((n_q, model.N_STATS))),
            ("xstats", _spec((n_q, model.N_STATS))),
            ("gstats", _spec((n_q, model.N_STATS))),
        ]
    )
    em.emit("tfm_train_step", step, ins, outs)


PRESETS = {
    # Small enough to AOT-compile + run fast under interpret-mode Pallas on
    # one CPU core; the E2E driver scales via --preset.
    "small": dict(cfg=model.tfm_config(vocab=64, seq=32, d_model=64, n_heads=4, n_layers=2), batch=8),
    "e2e": dict(cfg=model.tfm_config(vocab=256, seq=64, d_model=128, n_heads=4, n_layers=2), batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    args = ap.parse_args()

    em = Emitter(args.out_dir)
    print("emitting kernel artifacts…")
    emit_kernel_artifacts(em)
    print("emitting mlp artifacts…")
    emit_mlp(em)
    print(f"emitting transformer artifact (preset={args.preset})…")
    preset = PRESETS[args.preset]
    emit_tfm(em, preset["cfg"], preset["batch"])
    em.finish()
    print(f"manifest: {os.path.join(args.out_dir, 'manifest.txt')}")


if __name__ == "__main__":
    main()
