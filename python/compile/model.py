"""L2: JAX compute graphs implementing Algorithm 1 of the paper.

Every dense matmul goes through :func:`qlinear`, a ``jax.custom_vjp`` that is
the paper's Figure 3 for one layer:

    FPROP :   y    = X_hat @ W_hat                  (quantized operands)
    BPROP :   dX   = dY_hat @ W_hat^T
    WTGRAD:   dW   = X_hat^T @ dY_hat

with each of X, W, dY quantized by its *own* runtime ``(r, qmin, qmax)``
triple — so the Rust QPA can change bit-widths without recompiling.

QEM statistics (sum|x|, max|x|, sum|x_hat| under the applied scheme and under
candidate int8/16/24) are returned for all three tensors of every layer:
W / X stats come out of the forward pass as auxiliary outputs, and dY stats
ride out of the backward pass as the cotangent of a dummy ``gtap`` argument
(the custom_vjp is free to define that cotangent; jax.grad w.r.t. ``gtap``
then delivers it to the host) — one device round-trip per training step.

The element-wise quantization + stats math is the L1 Pallas kernels
(``kernels.quantize``, ``kernels.stats``); set ``APT_PALLAS=0`` to swap in the
pure-jnp oracle (bit-identical by pytest) when iterating on HLO size.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels import quantize as kq
from .kernels import stats as ks

USE_PALLAS = os.environ.get("APT_PALLAS", "1") != "0"

N_STATS = 6  # see kernels.stats
QP_LEN = 9  # (rx,qminx,qmaxx, rw,qminw,qmaxw, rg,qming,qmaxg)


def _fake_quant(x, r, qmin, qmax):
    if USE_PALLAS and x.ndim >= 2:
        return kq.fake_quant(x, r, qmin, qmax)
    return ref.fake_quant(x, r, qmin, qmax)


def _stats(x, r, qmin, qmax):
    """f32[6] QEM stats; candidate range = in-tensor max (see stats.py)."""
    rng = jnp.max(jnp.abs(x))
    if USE_PALLAS and x.ndim >= 2:
        return ks.qem_stats(x, r, qmin, qmax, rng)
    xq = ref.fake_quant(x, r, qmin, qmax)

    def cand(bits):
        q_top = float((1 << (bits - 1)) - 1)
        rc = jnp.where(rng > 0.0, jnp.exp2(jnp.ceil(jnp.log2(rng / q_top))), 1.0)
        return jnp.sum(jnp.abs(jnp.clip(jnp.round(x / rc), -q_top - 1.0, q_top) * rc))

    return jnp.stack(
        [
            jnp.sum(jnp.abs(x)),
            rng,
            jnp.sum(jnp.abs(xq)),
            cand(8),
            cand(16),
            cand(24),
        ]
    )


# --------------------------------------------------------------------------
# qlinear: the quantized matmul primitive (Algorithm 1, one layer)
# --------------------------------------------------------------------------


@jax.custom_vjp
def qlinear(x, w, qp, gtap):
    """Quantized ``x @ w``.

    Args:
      x: f32[m, k] activations.
      w: f32[k, n] weights.
      qp: f32[9] quant params ``(rx,qminx,qmaxx, rw,qminw,qmaxw, rg,qming,qmaxg)``.
      gtap: f32[3, 6] dummy whose cotangent carries the (W, X, dY) QEM stats.

    All QEM statistics are produced inside the *backward* rule: the
    custom_vjp body is opaque to JAX's JVP tracing, which keeps the Pallas
    stats kernel out of differentiation (interpret-mode pallas_call cannot
    be traced under JVP) and costs one extra elementwise pass instead of a
    second forward.
    """
    del gtap
    xh = _fake_quant(x, qp[0], qp[1], qp[2])
    wh = _fake_quant(w, qp[3], qp[4], qp[5])
    return xh @ wh


def _qlinear_fwd(x, w, qp, gtap):
    del gtap
    xh = _fake_quant(x, qp[0], qp[1], qp[2])
    wh = _fake_quant(w, qp[3], qp[4], qp[5])
    return xh @ wh, (x, w, qp)


def _qlinear_bwd(res, g):
    x, w, qp = res
    xh = _fake_quant(x, qp[0], qp[1], qp[2])
    wh = _fake_quant(w, qp[3], qp[4], qp[5])
    gh = _fake_quant(g, qp[6], qp[7], qp[8])
    dx = gh @ wh.T  # BPROP on quantized operands
    dw = xh.T @ gh  # WTGRAD on quantized operands
    stats = jnp.stack(
        [
            _stats(w, qp[3], qp[4], qp[5]),
            _stats(x, qp[0], qp[1], qp[2]),
            _stats(g, qp[6], qp[7], qp[8]),
        ]
    )
    return dx, dw, jnp.zeros_like(qp), stats


qlinear.defvjp(_qlinear_fwd, _qlinear_bwd)


def qlinear_nd(x, w, qp, gtap):
    """qlinear for inputs of rank ≥ 2 (flattens leading dims)."""
    lead = x.shape[:-1]
    y = qlinear(x.reshape((-1, x.shape[-1])), w, qp, gtap)
    return y.reshape(lead + (w.shape[-1],))


def fwd_stats(x, w, qp):
    """(wstats, xstats) for one qlinear — forward-side QEM inputs."""
    x2 = x.reshape((-1, x.shape[-1]))
    return _stats(w, qp[3], qp[4], qp[5]), _stats(x2, qp[0], qp[1], qp[2])


# --------------------------------------------------------------------------
# MLP classifier (the Rust integration-test model + quickstart artifact)
# --------------------------------------------------------------------------

MLP_DIMS = (64, 128, 64, 10)  # in, hidden…, classes


def mlp_init(key, dims=MLP_DIMS):
    """He-initialized (w, b) pairs, matching the paper's init assumption."""
    params = []
    for i in range(len(dims) - 1):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (dims[i], dims[i + 1]), jnp.float32)
        w = w * jnp.sqrt(2.0 / dims[i])
        params.append((w, jnp.zeros((dims[i + 1],), jnp.float32)))
    return params


def mlp_n_q(dims=MLP_DIMS) -> int:
    return len(dims) - 1


def _softmax_xent(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def mlp_loss(params, x, labels, qparams, gtaps):
    """Quantized forward pass + xent."""
    h = x
    n = len(params)
    for i, (w, b) in enumerate(params):
        h = qlinear(h, w, qparams[i], gtaps[i]) + b
        if i < n - 1:
            h = jax.nn.relu(h)
    return _softmax_xent(h, labels)


def mlp_train_step(params, x, labels, qparams, gtaps, lr):
    """One SGD step. Returns (new_params, loss, wstats, xstats, gstats),
    the stats stacks each f32[n_q, 6] (see kernels.stats for the layout)."""
    loss, (gparams, ggtaps) = jax.value_and_grad(mlp_loss, argnums=(0, 4))(
        params, x, labels, qparams, gtaps
    )
    new_params = jax.tree_util.tree_map(lambda p, g: p - lr * g, params, gparams)
    wstats, xstats, gstats = ggtaps[:, 0], ggtaps[:, 1], ggtaps[:, 2]
    return new_params, loss, wstats, xstats, gstats


def mlp_eval(params, x, labels, qparams, gtaps):
    """Quantized-forward accuracy + mean loss (deployment-int8 check)."""
    h = x
    for i, (w, b) in enumerate(params):
        h = qlinear(h, w, qparams[i], gtaps[i]) + b
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    acc = jnp.mean((jnp.argmax(h, axis=-1) == labels).astype(jnp.float32))
    return acc, _softmax_xent(h, labels)


# --------------------------------------------------------------------------
# Transformer LM (the E2E driver's model)
# --------------------------------------------------------------------------


def tfm_config(vocab=256, seq=64, d_model=128, n_heads=4, n_layers=2, d_ff=None):
    return dict(
        vocab=vocab,
        seq=seq,
        d_model=d_model,
        n_heads=n_heads,
        n_layers=n_layers,
        d_ff=d_ff or 4 * d_model,
    )


# Quantized matmuls per block: wq, wk, wv, wo, w1, w2  (attention
# score/value matmuls and layernorms stay f32 — see DESIGN.md §6).
TFM_Q_PER_BLOCK = 6


def tfm_n_q(cfg) -> int:
    return cfg["n_layers"] * TFM_Q_PER_BLOCK + 1  # +1 output head


def tfm_init(key, cfg):
    """Parameter pytree: dict of name → array. Deterministic ordering."""
    d, v, s, ff = cfg["d_model"], cfg["vocab"], cfg["seq"], cfg["d_ff"]
    p = {}

    def dense(key, shape, scale):
        return jax.random.normal(key, shape, jnp.float32) * scale

    key, k = jax.random.split(key)
    p["embed"] = dense(k, (v, d), 0.02)
    key, k = jax.random.split(key)
    p["pos"] = dense(k, (s, d), 0.02)
    for i in range(cfg["n_layers"]):
        pre = f"b{i}_"
        for name, shape in (
            ("wq", (d, d)),
            ("wk", (d, d)),
            ("wv", (d, d)),
            ("wo", (d, d)),
            ("w1", (d, ff)),
            ("w2", (ff, d)),
        ):
            key, k = jax.random.split(key)
            p[pre + name] = dense(k, shape, (2.0 / shape[0]) ** 0.5)
        p[pre + "ln1_g"] = jnp.ones((d,), jnp.float32)
        p[pre + "ln1_b"] = jnp.zeros((d,), jnp.float32)
        p[pre + "ln2_g"] = jnp.ones((d,), jnp.float32)
        p[pre + "ln2_b"] = jnp.zeros((d,), jnp.float32)
    p["lnf_g"] = jnp.ones((d,), jnp.float32)
    p["lnf_b"] = jnp.zeros((d,), jnp.float32)
    key, k = jax.random.split(key)
    p["head"] = dense(k, (d, v), (1.0 / d) ** 0.5)
    return p


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def tfm_forward(p, tokens, cfg, qparams, gtaps):
    """Causal LM forward with quantized projections; returns logits + stats."""
    d, h = cfg["d_model"], cfg["n_heads"]
    hd = d // h
    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos"][None, :s, :]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.float32(-1e9)

    qi = 0

    def ql(x_, w_):
        nonlocal qi
        y = qlinear_nd(x_, w_, qparams[qi], gtaps[qi])
        qi += 1
        return y

    for i in range(cfg["n_layers"]):
        pre = f"b{i}_"
        xn = _layernorm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        q = ql(xn, p[pre + "wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        k = ql(xn, p[pre + "wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        v = ql(xn, p[pre + "wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v).transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + ql(o, p[pre + "wo"])
        xn = _layernorm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        x = x + ql(jax.nn.relu(ql(xn, p[pre + "w1"])), p[pre + "w2"])

    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = ql(x, p["head"])
    return logits


def tfm_loss(p, tokens, targets, cfg, qparams, gtaps):
    logits = tfm_forward(p, tokens, cfg, qparams, gtaps)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def tfm_train_step(p, m, v_, tokens, targets, cfg, qparams, gtaps, lr, step):
    """One Adam step. Returns (p', m', v', loss, wstats, xstats, gstats)."""
    loss, (gp, ggtaps) = jax.value_and_grad(tfm_loss, argnums=(0, 5))(
        p, tokens, targets, cfg, qparams, gtaps
    )
    b1, b2, eps = 0.9, 0.999, 1e-8
    m2 = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, gp)
    v2 = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v_, gp)
    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    p2 = jax.tree_util.tree_map(
        lambda w, mm, vv: w - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps), p, m2, v2
    )
    wstats, xstats, gstats = ggtaps[:, 0], ggtaps[:, 1], ggtaps[:, 2]
    return p2, m2, v2, loss, wstats, xstats, gstats


# --------------------------------------------------------------------------
# Default quant params helper (all-int8, paper's starting point)
# --------------------------------------------------------------------------


def default_qparams(n_q: int, bits=(8, 8, 16), assumed_range=8.0):
    """Initial qparams[n_q, 9]: (x, w, g) at the given bit-widths.

    The Rust controller replaces these with live QPA values each step; these
    defaults only matter for step 0 and for pytest.
    """
    row = []
    for b in bits:
        r, qmin, qmax = ref.scheme_params(assumed_range, b)
        row += [r, qmin, qmax]
    # reorder: helper computes (x, w, g) already in the qp layout
    return jnp.tile(jnp.asarray(row, jnp.float32)[None, :], (n_q, 1))
