"""L1 Pallas kernel: QEM statistics with multi-candidate bit-widths.

The coordinator (Rust QEM/QPA) needs, per quantized tensor:

    sum|x|, max|x|, and sum|x_hat| under the *applied* scheme plus under the
    candidate bit-widths {8, 16, 24} — so that a single device round-trip
    lets QPA run the paper's "increase n by 8 until Diff < T" loop without
    touching the raw data again (DESIGN.md §6.1).

Output layout (f32[6]):
    [0] sum|x|
    [1] max|x|
    [2] sum|x_hat| under applied (r, qmin, qmax)
    [3] sum|x_hat| under candidate int8   (range from in-tensor max)
    [4] sum|x_hat| under candidate int16
    [5] sum|x_hat| under candidate int24

TPU design: two-pass reduction. Pass 1 (this kernel, gridded) reduces each
row-tile into a partial-stats row; pass 2 (tiny, single block) folds partials.
Candidate resolutions depend on the global max, so candidate sums are computed
in pass 2 from the *quantization-invariant* trick: they need the raw data.
Instead we compute candidate sums in pass 1 using the applied range scaled to
each candidate width — exact when the applied range tracks the true max
(which QPA guarantees within its update interval); the pure-jnp oracle in
`ref.py` + pytest pin this contract.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256

N_STATS = 6
CANDIDATE_BITS = (8, 16, 24)


def _make_stats_kernel(m: int, bm: int):
    """Kernel closure over the true row count (partial tiles are NaN-padded
    by Pallas; reductions must mask them out)."""

    def _stats_kernel(params_ref, x_ref, o_ref):
        r = params_ref[0, 0]
        qmin = params_ref[0, 1]
        qmax = params_ref[0, 2]
        rng = params_ref[0, 3]  # range estimate used for candidate schemes

        i = pl.program_id(0)
        x = x_ref[...]
        rows = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
        valid = rows + i * bm < m
        x = jnp.where(valid, x, 0.0)
        ax = jnp.abs(x)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        def cand_sum(bits):
            q_top = float((1 << (bits - 1)) - 1)
            # r_c = 2^ceil(log2(range / q_top)); guard range<=0 with r_c=1.
            rc = jnp.where(rng > 0.0, jnp.exp2(jnp.ceil(jnp.log2(rng / q_top))), 1.0)
            lo = -float(1 << (bits - 1))
            hi = q_top
            return jnp.sum(jnp.abs(jnp.clip(jnp.round(x / rc), lo, hi) * rc))

        sum_abs = jnp.sum(ax)
        max_abs = jnp.max(ax)
        sum_q = jnp.sum(jnp.abs(jnp.clip(jnp.round(x / r), qmin, qmax) * r))
        c8, c16, c24 = (cand_sum(b) for b in CANDIDATE_BITS)

        prev = o_ref[0, :]
        acc = jnp.stack(
            [
                prev[0] + sum_abs,
                jnp.maximum(prev[1], max_abs),
                prev[2] + sum_q,
                prev[3] + c8,
                prev[4] + c16,
                prev[5] + c24,
            ]
        )
        o_ref[0, :] = acc

    return _stats_kernel


@functools.partial(jax.jit, static_argnames=("block_rows",))
def qem_stats_pallas(x, params, *, block_rows: int = BLOCK_ROWS):
    """Compute the 6 QEM statistics of a 2-D array.

    Args:
      x: f32[m, n].
      params: f32[4] — ``(r, qmin, qmax, range_estimate)``.
    Returns:
      f32[6] as documented in the module docstring.
    """
    m, n = x.shape
    bm = min(block_rows, m)
    grid = (pl.cdiv(m, bm),)
    out = pl.pallas_call(
        _make_stats_kernel(m, bm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, N_STATS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, N_STATS), jnp.float32),
        interpret=True,
    )(params.reshape(1, 4), x)
    return out[0]


def qem_stats(x, r, qmin, qmax, range_estimate=None):
    """Rank-agnostic wrapper; defaults the candidate range to max|x|."""
    x2 = x.reshape((-1, x.shape[-1])) if x.ndim >= 2 else x.reshape((1, -1))
    if range_estimate is None:
        range_estimate = jnp.max(jnp.abs(x))
    params = jnp.stack(
        [
            jnp.asarray(r, jnp.float32),
            jnp.asarray(qmin, jnp.float32),
            jnp.asarray(qmax, jnp.float32),
            jnp.asarray(range_estimate, jnp.float32),
        ]
    )
    return qem_stats_pallas(x2, params)
