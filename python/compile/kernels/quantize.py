"""L1 Pallas kernel: fake-quantization ``x_hat = r * clamp(round(x / r))``.

TPU design (see DESIGN.md §Hardware-Adaptation): the input is streamed
HBM→VMEM in ``(BLOCK_ROWS, cols)`` tiles; the quantization parameters
``(r, qmin, qmax)`` are a single (1,3) scalar block broadcast to every grid
step (on real TPU they would live in SMEM via scalar prefetch). The kernel is
purely element-wise, so the VPU (8×128 lanes) processes a full tile per pass.

Must run with ``interpret=True`` on this CPU-only box — real TPU lowering
emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM tile. 256 rows × ≤2048 cols × 4 B ≈ 2 MiB — comfortably
# inside the ~16 MiB VMEM budget with double buffering.
BLOCK_ROWS = 256


def _fake_quant_kernel(params_ref, x_ref, o_ref):
    r = params_ref[0, 0]
    qmin = params_ref[0, 1]
    qmax = params_ref[0, 2]
    x = x_ref[...]
    o_ref[...] = jnp.clip(jnp.round(x / r), qmin, qmax) * r


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fake_quant_pallas(x, params, *, block_rows: int = BLOCK_ROWS):
    """Fake-quantize a 2-D array with a Pallas kernel.

    Args:
      x: f32[m, n] input.
      params: f32[3] — ``(r, qmin, qmax)`` with qmin/qmax the *code* bounds.
      block_rows: VMEM tile height.
    Returns:
      f32[m, n] dequantized fixed-point values.
    """
    m, n = x.shape
    bm = min(block_rows, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 3), lambda i: (0, 0)),  # broadcast scalar tile
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(params.reshape(1, 3), x)


def fake_quant(x, r, qmin, qmax):
    """Convenience wrapper matching ``ref.fake_quant``'s signature.

    Handles any rank by flattening to 2-D for the kernel.
    """
    shape = x.shape
    x2 = x.reshape((-1, shape[-1])) if x.ndim >= 2 else x.reshape((1, -1))
    params = jnp.stack(
        [jnp.asarray(r, jnp.float32), jnp.asarray(qmin, jnp.float32), jnp.asarray(qmax, jnp.float32)]
    )
    out = fake_quant_pallas(x2, params)
    return out.reshape(shape)
