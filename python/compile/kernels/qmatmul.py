"""L1 Pallas kernel: fused quantize → integer matmul → rescale.

The paper's hot-spot is the fixed-point GEMM ``r1*r2*(I1 @ I2)`` (Eq. 12).
On AVX2 the authors tile over registers; the TPU re-think (DESIGN.md
§Hardware-Adaptation) tiles over the MXU:

  grid = (M/bm, N/bn, K/bk); per step the (bm×bk) X-tile and (bk×bn) W-tile
  are staged in VMEM, quantized to integer codes by the VPU, pushed through a
  ``dot_general`` (on TPU: one MXU systolic pass, int8×int8→int32), and the
  i32 partial products accumulate into the (bm×bn) output tile which stays
  VMEM-resident across the K loop; the final K step applies the scalar
  rescale ``r1*r2``.

Codes are carried in f32 here (exact for |code| < 2^24, i.e. up to int24)
so the kernel is bit-exact to the integer pipeline while staying executable
under ``interpret=True`` on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile: 128×128 output, K panels of 128.
BM, BN, BK = 128, 128, 128


def _make_qmatmul_kernel(kdim: int, bk: int):
    """Kernel closure over the true contraction length: Pallas NaN-pads
    partial K tiles, so out-of-range codes are masked to 0 (a 0 code adds
    nothing to the i32 accumulator — the same trick an int8 MXU pass uses)."""

    def _qmatmul_kernel(params_ref, x_ref, w_ref, o_ref):
        rx = params_ref[0, 0]
        qminx = params_ref[0, 1]
        qmaxx = params_ref[0, 2]
        rw = params_ref[0, 3]
        qminw = params_ref[0, 4]
        qmaxw = params_ref[0, 5]

        k = pl.program_id(2)
        nk = pl.num_programs(2)

        x = x_ref[...]
        w = w_ref[...]
        kx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + k * bk
        kw = jax.lax.broadcasted_iota(jnp.int32, w.shape, 0) + k * bk
        ix = jnp.where(kx < kdim, jnp.clip(jnp.round(x / rx), qminx, qmaxx), 0.0)
        iw = jnp.where(kw < kdim, jnp.clip(jnp.round(w / rw), qminw, qmaxw), 0.0)
        # On TPU: int8 codes through the MXU with preferred_element_type=int32.
        part = jnp.dot(ix, iw, preferred_element_type=jnp.float32)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += part

        @pl.when(k == nk - 1)
        def _rescale():
            o_ref[...] *= rx * rw

    return _qmatmul_kernel


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def qmatmul_pallas(x, w, params, *, bm: int = BM, bn: int = BN, bk: int = BK):
    """Quantized matmul ``x_hat @ w_hat`` via the fused Pallas kernel.

    Args:
      x: f32[m, k]; w: f32[k, n].
      params: f32[6] — ``(rx, qminx, qmaxx, rw, qminw, qmaxw)``.
    Returns:
      f32[m, n] — bit-exact to ``ref.qmatmul``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    grid = (pl.cdiv(m, bm_), pl.cdiv(n, bn_), pl.cdiv(k, bk_))
    return pl.pallas_call(
        _make_qmatmul_kernel(k, bk_),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 6), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(params.reshape(1, 6), x, w)


def qmatmul(x, w, rx, qminx, qmaxx, rw, qminw, qmaxw):
    """Signature-compatible twin of ``ref.qmatmul``."""
    params = jnp.stack(
        [jnp.asarray(v, jnp.float32) for v in (rx, qminx, qmaxx, rw, qminw, qmaxw)]
    )
    return qmatmul_pallas(x, w, params)


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK) -> int:
    """Estimated VMEM working set of one grid step (f32 staging + i32 acc).

    Used by the §Perf analysis in EXPERIMENTS.md: x-tile + w-tile + their
    code copies + output accumulator, double-buffered inputs.
    """
    tile_in = (bm * bk + bk * bn) * 4  # staged f32 tiles
    codes = (bm * bk + bk * bn) * 1  # int8 codes on real TPU
    acc = bm * bn * 4  # i32 accumulator
    return 2 * tile_in + codes + acc  # ×2: double buffering of inputs
