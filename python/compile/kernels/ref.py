"""Pure-jnp reference oracle for the APT kernels.

Everything in this file is the *specification*: the Pallas kernels
(`quantize.py`, `stats.py`, `qmatmul.py`) and the Rust `fixedpoint` module are
tested against these functions.

Quantization scheme (paper Appendix B, "scheme 1"):
    a fixed-point number is ``(sign, (n-1)-bit integer, global resolution r)``
    with ``r = 2**s``, ``s = ceil(log2(Z / (2**(n-1) - 1)))`` for max-abs ``Z``;
    code ``I = round(F / r)`` clamped to ``[-2**(n-1), 2**(n-1) - 1]``;
    dequantized value ``F_hat = r * I``.

QEM (paper Eq. 2):
    ``Diff = log2(|sum|x| - sum|x_hat|| / sum|x| + 1)``.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np


def resolution_exponent(max_abs: float, n_bits: int) -> int:
    """``s = ceil(log2(Z / (2^(n-1) - 1)))`` — the paper's quantization scale.

    For ``max_abs == 0`` the data is all-zero; any resolution represents it
    exactly, we pick ``s = -(n-1)`` so the range is ~[-1, 1).
    """
    q_top = float((1 << (n_bits - 1)) - 1)
    if max_abs <= 0.0 or not math.isfinite(max_abs):
        return -(n_bits - 1)
    return int(math.ceil(math.log2(max_abs / q_top)))


def scheme_params(max_abs: float, n_bits: int) -> tuple[float, float, float]:
    """Return ``(r, qmin, qmax)`` for bit-width ``n_bits`` covering ``max_abs``.

    ``qmin/qmax`` are the *code* bounds (integers as f32), so the represented
    range is ``[r*qmin, r*qmax]`` (paper Table 4 column 3).
    """
    s = resolution_exponent(max_abs, n_bits)
    r = 2.0**s
    qmin = -float(1 << (n_bits - 1))
    qmax = float((1 << (n_bits - 1)) - 1)
    return r, qmin, qmax


def quantize_codes(x, r, qmin, qmax):
    """Integer codes ``I = clamp(round(x / r))`` (as f32 values, exact ints)."""
    return jnp.clip(jnp.round(x / r), qmin, qmax)


def fake_quant(x, r, qmin, qmax):
    """Dequantized fixed-point value ``x_hat = r * I`` — the oracle."""
    return quantize_codes(x, r, qmin, qmax) * r


def qem_stats(x, r, qmin, qmax):
    """QEM statistics ``(sum|x|, sum|x_hat|, max|x|)`` for one tensor."""
    xq = fake_quant(x, r, qmin, qmax)
    return (
        jnp.sum(jnp.abs(x)),
        jnp.sum(jnp.abs(xq)),
        jnp.max(jnp.abs(x)),
    )


def qem_diff(sum_abs: float, sum_abs_q: float) -> float:
    """Paper Eq. 2. ``Diff = log2(|m_x - m_xhat| / m_x + 1)`` (host-side)."""
    if sum_abs <= 0.0:
        return 0.0
    return math.log2(abs(sum_abs - sum_abs_q) / sum_abs + 1.0)


def qmatmul(x, w, rx, qminx, qmaxx, rw, qminw, qmaxw):
    """Quantized matmul: ``(rx*rw) * (Ix @ Iw)`` (paper Eq. 12).

    Computing on codes then rescaling is bit-exact to ``x_hat @ w_hat``
    because every code is an exact small integer in f32.
    """
    ix = quantize_codes(x, rx, qminx, qmaxx)
    iw = quantize_codes(w, rw, qminw, qmaxw)
    return (ix @ iw) * (rx * rw)


# --- host-side numpy twins (used by tests to cross-check jnp) -------------


def np_fake_quant(x: np.ndarray, r: float, qmin: float, qmax: float) -> np.ndarray:
    return np.clip(np.round(x / r), qmin, qmax) * r


def np_qem_diff(x: np.ndarray, r: float, qmin: float, qmax: float) -> float:
    s = float(np.sum(np.abs(x)))
    sq = float(np.sum(np.abs(np_fake_quant(x, r, qmin, qmax))))
    return qem_diff(s, sq)
