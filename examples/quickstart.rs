//! Quickstart: train the same classifier in float32 and with Adaptive
//! Precision Training through the unified `train::Session` API, and
//! compare accuracy + the bit-widths QPA chose.
//!
//!     cargo run --release --example quickstart -- [--model alexnet] [--iters 300]

use apt::exp::common::grad_mix_string;
use apt::nn::QuantMode;
use apt::train::SessionBuilder;
use apt::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let model = args.str_or("model", "alexnet");
    let iters = args.u64_or("iters", 300);

    println!("Adaptive Precision Training quickstart — {model}-mini, {iters} iters\n");

    let f32_run = SessionBuilder::classifier(&model).lr(0.01).train(iters);
    println!("float32 : eval acc {:.3}", f32_run.eval_acc);

    let mut cfg = apt::apt::AptConfig::default(); // α=0.01 β=0.025 δ=25 γ=2 T=3% Mode2
    cfg.init_phase_iters = iters / 10;
    let q_run = SessionBuilder::classifier(&model)
        .lr(0.01)
        .mode(QuantMode::Adaptive(cfg))
        .train(iters);
    println!("adaptive: eval acc {:.3}  (Δ {:+.3})", q_run.eval_acc, q_run.eval_acc - f32_run.eval_acc);
    println!("\nactivation-gradient bit mix over training (paper Table 1 style):");
    println!("  {}", grad_mix_string(&q_run.ledger));
    println!(
        "QPA updates: {} ({:.2}% of tensor-iterations)",
        q_run.ledger.total_updates(),
        100.0 * q_run.ledger.total_updates() as f64
            / (q_run.ledger.tensors.len().max(1) as u64 * iters) as f64
    );
    println!("\nweights & activations were pinned to int8 the whole run —");
    println!("the trained int8 weights deploy directly (paper §1, Efficiency).");
}
