//! Serve quickstart: the full train→deploy loop in one file. Trains a
//! classifier with int8-pinned forward tensors through `train::Session`,
//! checkpoints it, freezes the checkpoint into a pre-quantized
//! `serve::FrozenModel`, verifies the frozen logits against the live
//! session bit-for-bit, then answers concurrent queries through the
//! micro-batching `serve::InferenceServer` (DESIGN.md §Serving).
//!
//!     cargo run --release --example serve_quickstart -- \
//!         [--model mlp] [--iters 80] [--requests 64]

use std::sync::Arc;

use apt::data::SynthImages;
use apt::nn::{models, QuantMode};
use apt::serve::{FrozenModel, InferenceServer, ServeConfig};
use apt::train::SessionBuilder;
use apt::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let model = args.str_or("model", "mlp");
    let iters = args.u64_or("iters", 80);
    let requests = args.usize_or("requests", 64);
    let mode = QuantMode::Static(8);

    // 1. Train one "epoch" with int8 weights/activations and checkpoint it.
    println!("training {model} (int8) for {iters} iters …");
    let mut session = SessionBuilder::classifier(&model).mode(mode).lr(0.01).build();
    session.run(iters).expect("host training cannot fail");
    let ckpt = std::env::temp_dir().join(format!("apt_serve_quickstart_{}.ckpt", std::process::id()));
    session.save_checkpoint(&ckpt).expect("writing checkpoint");
    println!("checkpoint: {}", ckpt.display());

    // 2. Freeze: reload the checkpoint into a forward-only model with the
    //    weights pre-quantized once into int8 codes.
    let frozen = FrozenModel::from_checkpoint(&ckpt, &model, mode).expect("freeze");
    println!("frozen {} ({} weights)", frozen.label(), frozen.precision());

    // 3. Parity spot-check: frozen serving is bit-identical to the
    //    training-side eval path (see rust/tests/test_serve.rs).
    let data = SynthImages::new(1000, models::CLASSES, models::IN_C, models::IN_H, models::IN_W, 0.5);
    let (ex, ey) = data.eval_set(999, requests);
    let want = session.eval_logits(&ex);
    let got = frozen.forward(&ex, apt::kernels::global());
    let exact = want
        .data
        .iter()
        .zip(&got.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    // CI runs this example as the serve smoke test: a parity regression
    // must fail the run, not just print.
    assert!(exact, "frozen logits diverged from the session eval path");
    println!("frozen vs session eval: bit-identical");

    // 4. Serve: concurrent clients against the micro-batching server.
    let d = frozen.input_len();
    let server = InferenceServer::start(
        Arc::new(frozen),
        apt::kernels::global_arc(),
        ServeConfig { max_batch: 8, max_wait_us: 200, queue_cap: 128, workers: 2, ..ServeConfig::default() },
    )
    .expect("serve config is valid");
    let correct: usize = std::thread::scope(|scope| {
        let clients = 4usize;
        let mut handles = Vec::new();
        for c in 0..clients {
            let server = &server;
            let ex = &ex;
            let ey = &ey;
            handles.push(scope.spawn(move || {
                let mut correct = 0usize;
                let mut i = c;
                while i < requests {
                    let logits = server
                        .submit(ex.data[i * d..(i + 1) * d].to_vec())
                        .expect("submit")
                        .wait()
                        .expect("response");
                    let pred = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap();
                    if pred == ey[i] {
                        correct += 1;
                    }
                    i += clients;
                }
                correct
            }));
        }
        handles.into_iter().map(|h| h.join().expect("client")).sum()
    });
    let stats = server.shutdown();
    println!(
        "{requests} queries answered in {} batches (mean size {:.2}) — accuracy {:.3}",
        stats.batches,
        stats.mean_batch(),
        correct as f64 / requests as f64
    );
    let _ = std::fs::remove_file(&ckpt);
}
