//! The paper's headline RNN comparison (Fig 9a) as a standalone scenario:
//! unified int16 loses accuracy on a translation-style task, adaptive
//! precision recovers it by escalating only the tensors that need it.
//!
//!     cargo run --release --example adaptive_vs_static -- \
//!         [--iters 600] [--vocab 12] [--len 4]

use apt::exp;
use apt::util::cli::Args;

fn main() {
    let args = Args::from_env();
    exp::run("fig9a", &args);
    println!();
    // if artifacts are built, also run the transformer variant
    exp::run("fig9b", &args);
}
