//! Reproduce the paper's observation figures (Fig 1 + Fig 2) in one run:
//! gradient distributions per layer, range evolution, and the per-layer
//! bit-width sensitivity that motivates adaptive precision.
//!
//!     cargo run --release --example observe_distributions -- [--iters 200]

use apt::exp;
use apt::util::cli::Args;

fn main() {
    let args = Args::from_env();
    exp::run("fig1", &args);
    println!();
    exp::run("fig2", &args);
    println!();
    exp::run("fig11", &args);
}
