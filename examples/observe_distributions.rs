//! Reproduce the paper's observation figures (Fig 1 + Fig 2) in one run:
//! gradient distributions per layer, range evolution, and the per-layer
//! bit-width sensitivity that motivates adaptive precision — then turn the
//! same lens on *activations* through the calibration observers
//! (DESIGN.md §Calibration): one shared stats path for both the figures
//! and `apt calibrate`.
//!
//!     cargo run --release --example observe_distributions -- [--iters 200]

use apt::calib::{Calibrator, ObserverKind};
use apt::data::SynthImages;
use apt::exp;
use apt::fixedpoint::FormatFamily;
use apt::nn::{models, QuantMode};
use apt::train::SessionBuilder;
use apt::util::cli::Args;

fn main() {
    let args = Args::from_env();
    exp::run("fig1", &args);
    println!();
    exp::run("fig2", &args);
    println!();
    exp::run("fig11", &args);
    println!();
    observe_activations(args.u64_or("calib-iters", 60));
}

/// Per-site activation ranges under each calibration observer, side by
/// side: the exact envelope (minmax) against the smoothed/clipped
/// estimators — the choice `apt calibrate --observer` exposes.
fn observe_activations(iters: u64) {
    println!("== activation ranges through the calibration observers ==");
    let mut s = SessionBuilder::classifier("alexnet")
        .mode(QuantMode::Float32)
        .lr(0.01)
        .build();
    s.run(iters).expect("host training cannot fail");

    let kinds = [
        ObserverKind::MinMax,
        ObserverKind::Ema(0.01),
        ObserverKind::Percentile(99.99),
        ObserverKind::Kl,
    ];
    let mut tables = Vec::new();
    for kind in kinds {
        let mut cal =
            Calibrator::from_net("alexnet", s.net(), kind).expect("alexnet exports to the IR");
        let mut data = SynthImages::new(
            1000,
            models::CLASSES,
            models::IN_C,
            models::IN_H,
            models::IN_W,
            0.5,
        );
        for _ in 0..8 {
            let (x, _) = data.batch(32);
            cal.observe(&x);
        }
        tables.push(cal.finish(FormatFamily::FixedPoint, 8, false));
    }

    let head: String = tables.iter().map(|t| format!("{:>18}", t.observer)).collect();
    println!("{:<10}{head}", "site");
    for i in 0..tables[0].sites.len() {
        let row: String =
            tables.iter().map(|t| format!("{:>18.5}", t.sites[i].max_abs)).collect();
        println!("{:<10}{row}", tables[0].sites[i].name);
    }
    println!("minmax tracks the outlier envelope; percentile/kl clip it");
}
