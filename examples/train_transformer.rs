//! E2E driver: train a Transformer LM through the FULL three-layer stack —
//! Rust coordinator (QEM/QPA host control) → PJRT CPU client → AOT HLO
//! containing the Pallas-derived quantized train step — behind the same
//! `train::Session` surface as the host paths (DESIGN.md §Session-API).
//!
//! Python never runs here: the artifact was built once by `make artifacts`.
//!
//!     cargo run --release --example train_transformer -- \
//!         [--steps 200] [--lr 3e-3] [--mode adaptive|int16|float32] \
//!         [--artifacts artifacts] [--log results/e2e_loss.csv]
//!
//! Model size is fixed by the artifact (see `python/compile/aot.py`
//! --preset); scaling toward the paper's sizes is a preset knob, not a code
//! change (DESIGN.md §2).

use apt::coordinator::{tfm_slot_names, tokens_value};
use apt::data::lm_batch;
use apt::nn::QuantMode;
use apt::runtime::Runtime;
use apt::train::{PjrtBackend, Session};
use apt::util::cli::Args;
use apt::util::out::Csv;
use apt::util::{Pcg32, Timer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.u64_or("steps", 200);
    let lr = args.f32_or("lr", 3e-3);
    let artifacts = args.str_or("artifacts", "artifacts");
    let log_path = args.str_or("log", "results/e2e_loss.csv");
    let mode = match args.str_or("mode", "adaptive").as_str() {
        "float32" | "f32" => QuantMode::Float32,
        "adaptive" => {
            let mut cfg = apt::apt::AptConfig::default();
            cfg.init_phase_iters = (steps / 10).max(1);
            QuantMode::Adaptive(cfg)
        }
        s if s.starts_with("int") => QuantMode::Static(s[3..].parse()?),
        other => anyhow::bail!("unknown mode {other:?}"),
    };

    let mut rt = Runtime::new(&artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    let spec = rt
        .manifest
        .get("tfm_train_step")
        .ok_or_else(|| anyhow::anyhow!("tfm_train_step missing — run `make artifacts`"))?
        .clone();
    let n_q = spec.inputs[spec.input_index("qparams").unwrap()].dims[0];
    let n_layers = (n_q - 1) / 6;
    let toks = &spec.inputs[spec.input_index("tokens").unwrap()];
    let (batch, seq) = (toks.dims[0], toks.dims[1]);
    let vocab = spec.inputs[spec.input_index("p_embed").unwrap()].dims[0];
    let d_model = spec.inputs[spec.input_index("p_embed").unwrap()].dims[1];
    let n_params: usize = spec
        .inputs
        .iter()
        .filter(|s| s.name.starts_with("p_"))
        .map(|s| s.elements())
        .sum();
    println!(
        "model: vocab {vocab}, d_model {d_model}, {n_layers} blocks, seq {seq}, batch {batch} — {n_params} parameters, {n_q} quantized tensors"
    );

    let compile_t = Timer::start();
    rt.load("tfm_train_step")?;
    println!("artifact compiled in {:.2}s", compile_t.secs());

    let mut rng = Pcg32::seeded(7);
    let data = Box::new(move |_iter: u64| {
        let (tk, tg) = lm_batch(&mut rng, batch, seq, vocab);
        vec![tokens_value(&tk), tokens_value(&tg)]
    });
    let backend = PjrtBackend::new(
        &mut rt,
        "tfm_train_step",
        tfm_slot_names(n_layers),
        mode,
        42,
        lr,
        "tfm-e2e",
        data,
    )?;
    let mut session = Session::with_backend(backend);
    let mut csv = Csv::new(&log_path, &["step", "loss", "ms", "bits"]);
    let train_t = Timer::start();
    let mut last_loss = 0.0;
    for step in 0..steps {
        // `ms` times Session::step, i.e. host batch generation + qparams
        // render + artifact execution + stats feedback — the full training
        // step a user pays for, a few µs over the bare artifact call.
        let t = Timer::start();
        let loss = session.step()?;
        let ms = t.secs() * 1e3;
        last_loss = loss;
        let bits: String = session
            .grad_bits()
            .iter()
            .map(|(_, b)| b.to_string())
            .collect::<Vec<_>>()
            .join("/");
        csv.row(&[step.to_string(), format!("{loss:.4}"), format!("{ms:.1}"), bits.clone()]);
        if step % 10 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}  {ms:.0} ms  grad bits [{bits}]");
        }
    }
    csv.write()?;
    let total = train_t.secs();
    println!(
        "\ndone: {steps} steps in {total:.1}s ({:.1} ms/step, {:.0} tokens/s)",
        total * 1e3 / steps as f64,
        (steps as f64 * (batch * seq) as f64) / total
    );
    println!("final loss {last_loss:.4}; curve written to {log_path}");
    Ok(())
}
