//! Detection + segmentation under adaptive precision (Table 1's non-
//! classification rows): an SSD-lite detector and a deeplab-lite
//! segmenter trained f32 vs adaptive on synthetic scenes.
//!
//!     cargo run --release --example detection_lite -- [--iters 300]

use apt::data::{SynthDetection, SynthSegmentation};
use apt::exp::common::grad_mix_string;
use apt::nn::models::{DetectionNet, SegNet};
use apt::nn::{QuantMode, TrainCtx};
use apt::util::cli::Args;
use apt::util::Pcg32;

fn main() {
    let args = Args::from_env();
    let iters = args.u64_or("iters", 300);

    println!("== detection (SSD-lite, synthetic single-object scenes) ==");
    for (label, mode) in modes(iters) {
        let mut rng = Pcg32::seeded(7);
        let mut net = DetectionNet::new(3, mode, &mut rng);
        let mut data = SynthDetection::new(5, 3, 3, 16, 16);
        let mut ctx = TrainCtx::new();
        for it in 0..iters {
            ctx.iter = it;
            let (x, boxes, classes) = data.batch(16);
            net.train_step(&x, &boxes, &classes, 0.05, &mut ctx);
        }
        ctx.ledger.set_total_iters(iters);
        let (x, boxes, classes) = data.batch(128);
        let map = net.map_lite(&x, &boxes, &classes, &mut ctx);
        println!("  {label:<9} mAP-lite {map:.3}   {}", grad_mix_string(&ctx.ledger));
    }

    println!("\n== segmentation (deeplab-lite, synthetic masks) ==");
    for (label, mode) in modes(iters) {
        let mut rng = Pcg32::seeded(8);
        let mut net = SegNet::new(3, mode, &mut rng);
        let mut data = SynthSegmentation::new(6, 3, 3, 12, 12);
        let mut ctx = TrainCtx::new();
        for it in 0..iters {
            ctx.iter = it;
            let (x, labels) = data.batch(8);
            net.train_step(&x, &labels, &mut ctx);
        }
        ctx.ledger.set_total_iters(iters);
        let (x, labels) = data.batch(64);
        let miou = net.eval_miou(&x, &labels, &mut ctx);
        println!("  {label:<9} meanIoU {miou:.3}   {}", grad_mix_string(&ctx.ledger));
    }
    println!("\npaper shape (Table 1): adaptive ≈ float32 on both tasks");
}

fn modes(iters: u64) -> Vec<(&'static str, QuantMode)> {
    let mut cfg = apt::apt::AptConfig::default();
    cfg.init_phase_iters = iters / 10;
    vec![("float32", QuantMode::Float32), ("adaptive", QuantMode::Adaptive(cfg))]
}
